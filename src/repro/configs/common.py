"""Cell registry: every assigned (architecture × input shape) combination
becomes a `Cell` with abstract input specs, a step function, and sharding
rules — consumed by the dry-run, the smoke tests, and the roofline pass.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shard_rules
from repro.models import gnn, recsys, transformer as tf
from repro.train.optimizer import AdamWConfig, adamw_init, make_train_step

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    family: str
    kind: str                        # train | prefill | decode | serve
    model_cfg: Any
    step_fn: Callable                # pure fn(*inputs)
    input_specs: Callable[[], tuple]        # () -> tuple of abstract args
    in_shardings: Callable[[bool], tuple]   # multi_pod -> tuple of spec trees
    make_smoke_inputs: Callable[[Any, np.random.Generator], tuple] | None = None
    smoke_cfg: Any = None
    skip_reason: str | None = None
    donate_argnums: tuple = ()
    out_shardings: Callable | None = None   # multi_pod -> out spec tree
    smoke_step_fn: Callable | None = None   # step built against smoke_cfg
    # LM cells: rebuild (step, specs, shardings, ..., outs) for a variant
    # config — used by the dry-run's two-point loop-analysis correction.
    make_for_cfg: Callable | None = None
    # Mesh-coupled cells (the spfresh index: shard_map needs the mesh):
    # make_mesh_step(mesh, multi_pod) -> (step_fn, abstract_args)
    make_mesh_step: Any = None

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.shape}"


OPT = AdamWConfig()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ===========================================================================
# LM family
# ===========================================================================

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

LM_SMOKE_SHAPES = {
    "train_4k": dict(kind="train", seq=32, batch=2),
    "prefill_32k": dict(kind="prefill", seq=64, batch=2),
    "decode_32k": dict(kind="decode", seq=64, batch=4),
    "long_500k": dict(kind="decode", seq=128, batch=1),
}


def lm_cells(arch: str, cfg: tf.LMConfig, smoke: tf.LMConfig) -> list[Cell]:
    cells = []
    for shape_name, sh in LM_SHAPES.items():
        kind = sh["kind"]
        skip = None
        if shape_name == "long_500k":
            skip = (
                "pure full-attention arch: long_500k requires sub-quadratic "
                "attention (assignment rule; see DESIGN.md §5)"
            )

        def make(the_cfg, shape_name=shape_name, sh=sh, kind=kind):
            seq, batch = sh["seq"], sh["batch"]
            ssh = LM_SMOKE_SHAPES[shape_name]

            if kind == "train":
                def loss(params, b, _cfg=the_cfg):
                    return tf.loss_fn(params, b, _cfg)
                step = make_train_step(loss, OPT)

                def specs(_cfg=the_cfg, seq=seq, batch=batch):
                    p = tf.param_specs(_cfg)
                    o = jax.eval_shape(adamw_init, p)
                    b = {
                        "tokens": _sds((batch, seq), I32),
                        "labels": _sds((batch, seq), I32),
                    }
                    return (p, o, b)

                def shardings(multi_pod, _cfg=the_cfg):
                    ps = shard_rules.lm_param_specs(_cfg, multi_pod=multi_pod)
                    return (
                        ps,
                        shard_rules.opt_state_specs(ps),
                        shard_rules.lm_batch_specs("train", multi_pod=multi_pod),
                    )

                def smoke_inputs(scfg, rng, ssh=ssh):
                    params = tf.init_params(jax.random.PRNGKey(0), scfg)
                    opt = adamw_init(params)
                    toks = jnp.asarray(
                        rng.integers(0, scfg.vocab, size=(ssh["batch"], ssh["seq"])),
                        I32,
                    )
                    return (params, opt, {"tokens": toks, "labels": toks})

                return step, specs, shardings, smoke_inputs, (0, 1), None

            if kind == "prefill":
                def step(params, tokens, _cfg=the_cfg):
                    return tf.prefill(params, tokens, _cfg)

                def specs(_cfg=the_cfg, seq=seq, batch=batch):
                    return (tf.param_specs(_cfg), _sds((batch, seq), I32))

                def shardings(multi_pod, _cfg=the_cfg):
                    da = shard_rules.data_axes(multi_pod)
                    return (
                        shard_rules.lm_param_specs(_cfg, multi_pod=multi_pod),
                        P(da, None),
                    )

                def smoke_inputs(scfg, rng, ssh=ssh):
                    params = tf.init_params(jax.random.PRNGKey(0), scfg)
                    toks = jnp.asarray(
                        rng.integers(0, scfg.vocab, size=(ssh["batch"], ssh["seq"])),
                        I32,
                    )
                    return (params, toks)

                def outs(multi_pod):
                    da = shard_rules.data_axes(multi_pod)
                    return (P(da, "model"), shard_rules.lm_cache_specs(multi_pod))
                return step, specs, shardings, smoke_inputs, (), outs

            # decode
            def step(params, cache, tokens, pos, _cfg=the_cfg):
                return tf.decode_step(params, cache, tokens, pos, _cfg)

            def specs(_cfg=the_cfg, seq=seq, batch=batch):
                cache = jax.eval_shape(
                    lambda: tf.init_cache(_cfg, batch, seq)
                )
                return (
                    tf.param_specs(_cfg), cache, _sds((batch,), I32),
                    _sds((), I32),
                )

            def shardings(multi_pod, _cfg=the_cfg):
                da = shard_rules.data_axes(multi_pod)
                return (
                    shard_rules.lm_param_specs(_cfg, multi_pod=multi_pod),
                    shard_rules.lm_cache_specs(multi_pod),
                    P(da),
                    P(),
                )

            def smoke_inputs(scfg, rng, ssh=ssh):
                params = tf.init_params(jax.random.PRNGKey(0), scfg)
                cache = tf.init_cache(scfg, ssh["batch"], ssh["seq"])
                toks = jnp.asarray(
                    rng.integers(0, scfg.vocab, size=(ssh["batch"],)), I32
                )
                return (params, cache, toks, jnp.asarray(ssh["seq"] // 2, I32))

            def outs(multi_pod):
                da = shard_rules.data_axes(multi_pod)
                return (P(da, "model"), shard_rules.lm_cache_specs(multi_pod))
            return step, specs, shardings, smoke_inputs, (1,), outs

        step, specs, shardings, smoke_inputs, donate, outs = make(cfg)
        smoke_step = make(smoke)[0]
        cells.append(Cell(
            arch=arch, shape=shape_name, family="lm", kind=kind,
            model_cfg=cfg, smoke_cfg=smoke, step_fn=step, input_specs=specs,
            in_shardings=shardings, make_smoke_inputs=smoke_inputs,
            skip_reason=skip, donate_argnums=donate, smoke_step_fn=smoke_step,
            out_shardings=outs, make_for_cfg=make,
        ))
    return cells


# ===========================================================================
# GNN family (gat-cora)
# ===========================================================================

GNN_SHAPES = {
    # shape -> (kind, n_nodes, n_edges, d_feat, n_classes, extras)
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7),
    "minibatch_lg": dict(
        n_nodes=1024 + 1024 * 15 + 1024 * 150,
        n_edges=1024 * 15 + 1024 * 150 * 10 // 10 * 10,  # 15360 + 153600
        d_feat=602, n_classes=41, n_targets=1024,
    ),
    "ogb_products": dict(
        n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, n_classes=47
    ),
    "molecule": dict(
        n_nodes=30 * 128, n_edges=64 * 128, d_feat=32, n_classes=2,
        n_graphs=128, readout="mean",
    ),
}

GNN_SMOKE_SHAPES = {
    "full_graph_sm": dict(n_nodes=64, n_edges=256, d_feat=24, n_classes=7),
    "minibatch_lg": dict(
        n_nodes=8 + 8 * 3 + 8 * 6, n_edges=8 * 3 + 8 * 6, d_feat=16,
        n_classes=5, n_targets=8,
    ),
    "ogb_products": dict(n_nodes=128, n_edges=512, d_feat=12, n_classes=7),
    "molecule": dict(
        n_nodes=5 * 8, n_edges=8 * 8, d_feat=8, n_classes=2, n_graphs=8,
        readout="mean",
    ),
}


def gnn_cells(arch: str, base: gnn.GATConfig) -> list[Cell]:
    cells = []
    for shape_name, sh in GNN_SHAPES.items():
        cfg = dataclasses.replace(
            base, d_in=sh["d_feat"], n_classes=sh["n_classes"],
            readout=sh.get("readout", "none"), n_graphs=sh.get("n_graphs", 0),
        )
        ssh = GNN_SMOKE_SHAPES[shape_name]
        smoke = dataclasses.replace(
            base, d_in=ssh["d_feat"], n_classes=ssh["n_classes"],
            readout=ssh.get("readout", "none"), n_graphs=ssh.get("n_graphs", 0),
        )

        def make(the_cfg, sh=sh):
            def loss(params, b, _cfg=the_cfg):
                return gnn.loss_fn(params, b, _cfg)
            step = make_train_step(loss, OPT)

            def batch_struct(sh, _cfg):
                n, e = sh["n_nodes"], sh["n_edges"]
                # pad the edge list to shard over the full 512-device mesh
                # (padded edges carry src/dst = -1 and are ignored)
                e = ((e + 511) // 512) * 512
                b = {
                    "features": _sds((n, sh["d_feat"]), F32),
                    "edge_src": _sds((e,), I32),
                    "edge_dst": _sds((e,), I32),
                }
                if "n_graphs" in sh:
                    b["graph_ids"] = _sds((n,), I32)
                    b["labels"] = _sds((sh["n_graphs"],), I32)
                else:
                    b["labels"] = _sds((n,), I32)
                return b

            def specs(_cfg=the_cfg, sh=sh):
                p = gnn.param_specs(_cfg)
                o = jax.eval_shape(adamw_init, p)
                return (p, o, batch_struct(sh, _cfg))

            def shardings(multi_pod, _cfg=the_cfg, sh=sh):
                p = gnn.param_specs(_cfg)
                ps = shard_rules.gnn_param_specs(p)
                bs = shard_rules.gnn_batch_specs(
                    batch_struct(sh, _cfg), multi_pod=multi_pod
                )
                return (ps, shard_rules.opt_state_specs(ps), bs)

            def smoke_inputs(scfg, rng, ssh=ssh, shape_name=shape_name):
                params = gnn.init_params(jax.random.PRNGKey(0), scfg)
                opt = adamw_init(params)
                n, e = ssh["n_nodes"], ssh["n_edges"]
                if shape_name == "minibatch_lg":
                    # use the REAL fanout sampler for the sampled-training
                    # cell (fanouts chosen to reproduce ssh geometry)
                    from repro.data.graphs import CSRGraph, sample_subgraph

                    g = CSRGraph.random(
                        max(64, n), avg_degree=8, d_feat=ssh["d_feat"],
                        n_classes=ssh["n_classes"], seed=0,
                    )
                    targets = rng.choice(g.n_nodes, size=ssh["n_targets"],
                                         replace=False)
                    raw = sample_subgraph(g, targets, (3, 6),
                                          np.random.default_rng(1))
                    b = {
                        "features": jnp.asarray(raw["features"]),
                        "edge_src": jnp.asarray(raw["edge_src"]),
                        "edge_dst": jnp.asarray(raw["edge_dst"]),
                        "labels": jnp.asarray(raw["labels"]),
                    }
                    return (params, opt, b)
                b = {
                    "features": jnp.asarray(rng.normal(size=(n, ssh["d_feat"])), F32),
                    "edge_src": jnp.asarray(rng.integers(0, n, size=e), I32),
                    "edge_dst": jnp.asarray(rng.integers(0, n, size=e), I32),
                }
                if "n_graphs" in ssh:
                    g = ssh["n_graphs"]
                    b["graph_ids"] = jnp.asarray(
                        np.repeat(np.arange(g), n // g), I32
                    )
                    b["labels"] = jnp.asarray(
                        rng.integers(0, ssh["n_classes"], size=g), I32
                    )
                else:
                    labels = rng.integers(0, ssh["n_classes"], size=n).astype(np.int32)
                    if "n_targets" in ssh:
                        labels[ssh["n_targets"]:] = -1
                    b["labels"] = jnp.asarray(labels)
                return (params, opt, b)
            return step, specs, shardings, smoke_inputs

        step, specs, shardings, smoke_inputs = make(cfg)
        smoke_step = make(smoke)[0]
        cells.append(Cell(
            arch=arch, shape=shape_name, family="gnn", kind="train",
            model_cfg=cfg, smoke_cfg=smoke, step_fn=step, input_specs=specs,
            in_shardings=shardings, make_smoke_inputs=smoke_inputs,
            donate_argnums=(0, 1), smoke_step_fn=smoke_step,
        ))
    return cells


# ===========================================================================
# Recsys family
# ===========================================================================

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="serve", batch=1, n_candidates=1_000_000),
}

RECSYS_SMOKE_SHAPES = {
    "train_batch": dict(kind="train", batch=32),
    "serve_p99": dict(kind="serve", batch=8),
    "serve_bulk": dict(kind="serve", batch=64),
    "retrieval_cand": dict(kind="serve", batch=1, n_candidates=256),
}


def _recsys_cell(
    arch: str,
    shape_name: str,
    cfg,
    smoke_cfg,
    kind: str,
    make_step,          # cfg -> step_fn
    init_fn,
    batch_struct_fn,
    make_batch_fn,
    donate=(),
) -> Cell:
    def specs():
        p = jax.eval_shape(lambda k: init_fn(k, cfg), jax.random.PRNGKey(0))
        b = batch_struct_fn(cfg, RECSYS_SHAPES[shape_name])
        if kind == "train":
            o = jax.eval_shape(adamw_init, p)
            return (p, o, b)
        return (p, b)

    def shardings(multi_pod):
        p = jax.eval_shape(lambda k: init_fn(k, cfg), jax.random.PRNGKey(0))
        ps = shard_rules.recsys_param_specs(p, multi_pod=multi_pod)
        b = batch_struct_fn(cfg, RECSYS_SHAPES[shape_name])
        bs = shard_rules.recsys_batch_specs(b, multi_pod=multi_pod)
        if kind == "train":
            return (ps, shard_rules.opt_state_specs(ps), bs)
        return (ps, bs)

    def smoke_inputs(scfg, rng):
        params = init_fn(jax.random.PRNGKey(0), scfg)
        b = make_batch_fn(scfg, RECSYS_SMOKE_SHAPES[shape_name], rng)
        if kind == "train":
            return (params, adamw_init(params), b)
        return (params, b)

    return Cell(
        arch=arch, shape=shape_name, family="recsys", kind=kind,
        model_cfg=cfg, smoke_cfg=smoke_cfg, step_fn=make_step(cfg),
        input_specs=specs, in_shardings=shardings,
        make_smoke_inputs=smoke_inputs, donate_argnums=donate,
        smoke_step_fn=make_step(smoke_cfg),
    )
