"""two-tower-retrieval [recsys] embed_dim=256 tower_mlp=1024-512-256
interaction=dot — sampled-softmax retrieval [RecSys'19 (YouTube)].

This is the paper-flagship arch: ``retrieval_cand`` is exactly the ANN
query SPFresh serves (see repro/serve/retrieval.py + benchmarks)."""
import dataclasses

import jax.numpy as jnp

from repro.configs.common import OPT, RECSYS_SHAPES, Cell, _recsys_cell, _sds
from repro.models import recsys as R
from repro.train.optimizer import make_train_step

CONFIG = R.TwoTowerConfig(
    name="two-tower-retrieval",
    n_items=10_000_000,
    n_user_fields=8,
    user_vocab_per_field=100_000,
    embed_dim=256,
    tower_dims=(1024, 512, 256),
)

SMOKE = R.TwoTowerConfig(
    name="two-tower-smoke", n_items=512, n_user_fields=4,
    user_vocab_per_field=64, embed_dim=16, tower_dims=(32, 16),
)


def _batch_struct(cfg, sh, kind, shape_name):
    b = sh["batch"]
    out = {"user_fields": _sds((b, cfg.n_user_fields), jnp.int32)}
    if shape_name == "retrieval_cand":
        out["candidate_ids"] = _sds((sh["n_candidates"],), jnp.int32)
        return out
    out["item_ids"] = _sds((b,), jnp.int32)
    if kind == "train":
        out["item_logq"] = _sds((b,), jnp.float32)
    return out


def _make_batch(cfg, sh, rng, kind, shape_name):
    b = sh["batch"]
    out = {
        "user_fields": jnp.asarray(
            rng.integers(0, cfg.user_vocab_per_field,
                         size=(b, cfg.n_user_fields)), jnp.int32
        )
    }
    if shape_name == "retrieval_cand":
        out["candidate_ids"] = jnp.asarray(
            rng.integers(0, cfg.n_items, size=sh["n_candidates"]), jnp.int32
        )
        return out
    out["item_ids"] = jnp.asarray(rng.integers(0, cfg.n_items, size=b), jnp.int32)
    if kind == "train":
        out["item_logq"] = jnp.zeros((b,), jnp.float32)
    return out


# --------------------------------------------------------------------------
# §Perf iter 3 (beyond-paper flagship): retrieval_cand served by the SPFresh
# index instead of the brute-force 1M-candidate GEMM.  The item corpus lives
# in a document-sharded LIRE index over item-tower embeddings (dim 256,
# bf16); the user query runs the tower, then a distributed nprobe=16 search.
# --------------------------------------------------------------------------

def _ann_index_cfg():
    from repro.core.types import LireConfig

    # per-shard geometry: 10M items / 256 shards ≈ 40k items (+ replica
    # headroom) per device
    return LireConfig(
        dim=256, block_size=32, max_blocks_per_posting=4,   # cap 128
        num_blocks=4096, num_postings_cap=2048,
        num_vectors_cap=131072, vector_dtype="bfloat16",
        split_limit=96, merge_limit=12, reassign_range=16,
        reassign_budget=128, replica_count=2, nprobe=16,
    )


def _ann_make_mesh_step(mesh, multi_pod: bool):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.types import make_empty_state
    from repro.distributed import sharded_index as D
    from repro.distributed.sharding import recsys_param_specs

    icfg = _ann_index_cfg()
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n_shards = 512 if multi_pod else 256
    cfg = dataclasses.replace(CONFIG, dtype="bfloat16")

    search = D.make_search_step(mesh, icfg, k=10, shard_axes=axes, nprobe=16)

    def step(params, user_fields, state_stacked, alive):
        u = R.user_tower(params, user_fields, cfg)  # (1, 256)
        return search(state_stacked, u.astype(jnp.float32), alive)

    abstract = jax.eval_shape(lambda: make_empty_state(icfg))
    state_specs = jax.tree_util.tree_map(
        lambda x: _sds((n_shards, *x.shape), x.dtype), abstract
    )
    p_abs = jax.eval_shape(
        lambda k: R.twotower_init(k, cfg), jax.random.PRNGKey(0)
    )
    p_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        recsys_param_specs(p_abs, multi_pod=multi_pod),
        is_leaf=lambda x: isinstance(x, P),
    )
    ax_spec = axes if len(axes) > 1 else axes[0]
    jitted = jax.jit(
        step,
        in_shardings=(
            p_sh,
            NamedSharding(mesh, P(None, None)),
            jax.tree_util.tree_map(
                lambda x: NamedSharding(
                    mesh, P(ax_spec, *([None] * x.ndim))
                ),
                abstract,
            ),
            NamedSharding(mesh, P(None)),
        ),
    )
    args = (
        p_abs,
        _sds((1, CONFIG.n_user_fields), jnp.int32),
        state_specs,
        _sds((n_shards,), jnp.bool_),
    )
    return jitted, args


def cells() -> list[Cell]:
    out = []
    ann = Cell(
        arch="two-tower-retrieval", shape="retrieval_cand_ann",
        family="recsys", kind="serve",
        model_cfg=CONFIG, smoke_cfg=SMOKE, step_fn=None, input_specs=None,
        in_shardings=None, make_smoke_inputs=None,
    )
    ann.make_mesh_step = _ann_make_mesh_step
    out.append(ann)
    for shape_name, sh in RECSYS_SHAPES.items():
        kind = sh["kind"]
        if kind == "train":
            def make_step(cfg):
                return make_train_step(
                    lambda p, b, _cfg=cfg: R.twotower_loss(p, b, _cfg), OPT
                )
            donate = (0, 1)
        elif shape_name == "retrieval_cand":
            def make_step(cfg):
                def step(params, batch, _cfg=cfg):
                    return R.twotower_retrieval(params, batch, _cfg)
                return step
            donate = ()
        else:
            def make_step(cfg):
                def step(params, batch, _cfg=cfg):
                    return R.twotower_score_pairs(params, batch, _cfg)
                return step
            donate = ()
        # §Perf iter 2: the serving cells read a bf16-cast checkpoint —
        # halves table-gather + activation HBM traffic at iso-recall.
        cell_cfg = (
            dataclasses.replace(CONFIG, dtype="bfloat16")
            if shape_name == "retrieval_cand" else CONFIG
        )
        out.append(_recsys_cell(
            "two-tower-retrieval", shape_name, cell_cfg, SMOKE, kind, make_step,
            R.twotower_init,
            lambda cfg, s, _k=kind, _n=shape_name: _batch_struct(cfg, s, _k, _n),
            lambda cfg, s, rng, _k=kind, _n=shape_name: _make_batch(cfg, s, rng, _k, _n),
            donate=donate,
        ))
    return out
