"""deepfm [recsys] n_sparse=39 embed_dim=10 mlp=400-400-400 interaction=fm
[arXiv:1703.04247; paper]."""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.common import RECSYS_SHAPES, Cell, _recsys_cell, _sds
from repro.models import recsys as R
from repro.train.optimizer import make_train_step

CONFIG = R.DeepFMConfig(
    name="deepfm", n_fields=39, vocab_per_field=1_000_000, embed_dim=10,
    mlp_dims=(400, 400, 400),
)

SMOKE = R.DeepFMConfig(
    name="deepfm-smoke", n_fields=6, vocab_per_field=64, embed_dim=4,
    mlp_dims=(16, 16),
)


def _batch_struct(cfg, sh):
    b = sh["batch"] * sh.get("n_candidates", 1)
    out = {"fields": _sds((b, cfg.n_fields), jnp.int32)}
    if sh.get("kind") == "train":
        out["labels"] = _sds((b,), jnp.int32)
    return out


def _make_batch(cfg, sh, rng):
    b = sh["batch"] * sh.get("n_candidates", 1)
    out = {
        "fields": jnp.asarray(
            rng.integers(0, cfg.vocab_per_field, size=(b, cfg.n_fields)),
            jnp.int32,
        )
    }
    if sh.get("kind") == "train":
        out["labels"] = jnp.asarray(rng.integers(0, 2, size=b), jnp.int32)
    return out


def cells() -> list[Cell]:
    from repro.configs.common import OPT
    out = []
    for shape_name, sh in RECSYS_SHAPES.items():
        kind = "train" if sh["kind"] == "train" else "serve"
        if kind == "train":
            def make_step(cfg):
                return make_train_step(
                    lambda p, b, _cfg=cfg: R.deepfm_loss(p, b, _cfg), OPT
                )
            donate = (0, 1)
        else:
            # retrieval_cand for a ranking model = bulk-score 1M candidates
            def make_step(cfg):
                def step(params, batch, _cfg=cfg):
                    return R.deepfm_forward(params, batch, _cfg)
                return step
            donate = ()
        out.append(_recsys_cell(
            "deepfm", shape_name, CONFIG, SMOKE, kind, make_step,
            R.deepfm_init,
            lambda cfg, s, _k=kind: _batch_struct(cfg, {**s, "kind": _k}),
            lambda cfg, s, rng, _k=kind: _make_batch(cfg, {**s, "kind": _k}, rng),
            donate=donate,
        ))
    return out
