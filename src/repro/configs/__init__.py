"""Assigned-architecture registry.

``get_cells(arch)`` returns the (arch × shape) Cell list; ``all_cells()``
returns every cell (40 assigned + the paper's own spfresh cells).
Exact configs from the assignment are in the per-arch modules.
"""
from __future__ import annotations

from repro.configs.common import Cell

_ARCH_MODULES = [
    "granite_20b",
    "deepseek_7b",
    "qwen15_110b",
    "granite_moe_1b_a400m",
    "phi35_moe_42b_a6_6b",
    "gat_cora",
    "bert4rec",
    "mind",
    "two_tower_retrieval",
    "deepfm",
    "spfresh",
]

_CELLS: dict[str, list[Cell]] | None = None


def _load() -> dict[str, list[Cell]]:
    global _CELLS
    if _CELLS is None:
        import importlib

        _CELLS = {}
        for mod_name in _ARCH_MODULES:
            mod = importlib.import_module(f"repro.configs.{mod_name}")
            cells = mod.cells()
            assert cells, mod_name
            _CELLS[cells[0].arch] = cells
    return _CELLS


def arch_names() -> list[str]:
    return list(_load().keys())


def get_cells(arch: str) -> list[Cell]:
    return _load()[arch]


def get_cell(arch: str, shape: str) -> Cell:
    for c in _load()[arch]:
        if c.shape == shape:
            return c
    raise KeyError(f"{arch}/{shape}")


def all_cells(include_skipped: bool = True) -> list[Cell]:
    out = []
    for cells in _load().values():
        for c in cells:
            if include_skipped or c.skip_reason is None:
                out.append(c)
    return out
