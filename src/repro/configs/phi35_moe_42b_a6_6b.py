"""phi3.5-moe-42b-a6.6b [moe] 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16e top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf]."""
from repro.configs.common import lm_cells
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="phi3.5-moe-42b-a6.6b",
    vocab=32064,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    moe=True,
    n_experts=16,
    moe_top_k=2,
    dtype="bfloat16",
    scan_unroll=1,    # scanned; dry-run corrects analysis w/ 2-point unroll probe
)

SMOKE = LMConfig(
    name="phi35-moe-smoke",
    vocab=256, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
    moe=True, n_experts=4, moe_top_k=2, dtype="float32", kv_chunk=16,
)


def cells():
    return lm_cells("phi3.5-moe-42b-a6.6b", CONFIG, SMOKE)
