"""bert4rec [recsys] embed_dim=64 n_blocks=2 n_heads=2 seq_len=200
interaction=bidir-seq [arXiv:1904.06690; paper]."""
import jax.numpy as jnp
import numpy as np

from repro.configs.common import OPT, RECSYS_SHAPES, Cell, _recsys_cell, _sds
from repro.models import recsys as R
from repro.train.optimizer import make_train_step

CONFIG = R.Bert4RecConfig(
    # 2^20 - 1 so the (n_items + 1 [MASK]) table rows shard 16-way
    name="bert4rec", n_items=1_048_575, embed_dim=64, n_blocks=2, n_heads=2,
    d_ff=256, seq_len=200,
)

SMOKE = R.Bert4RecConfig(
    name="bert4rec-smoke", n_items=128, embed_dim=16, n_blocks=2, n_heads=2,
    d_ff=32, seq_len=12,
)


N_MASK = 4  # masked positions scored per sequence (BERT4Rec masks ~2%)


def _batch_struct(cfg, sh, kind, shape_name):
    b = sh["batch"]
    out = {"items": _sds((b, cfg.seq_len), jnp.int32)}
    if kind == "train":
        out["mask_pos"] = _sds((b, N_MASK), jnp.int32)
        out["mask_label"] = _sds((b, N_MASK), jnp.int32)
    elif shape_name == "serve_bulk":
        out["pair_items"] = _sds((b,), jnp.int32)
    elif shape_name == "retrieval_cand":
        out["candidate_ids"] = _sds((sh["n_candidates"],), jnp.int32)
    return out


def _make_batch(cfg, sh, rng, kind, shape_name):
    b = sh["batch"]
    items = rng.integers(0, cfg.n_items, size=(b, cfg.seq_len)).astype(np.int32)
    out = {"items": jnp.asarray(items)}
    if kind == "train":
        n_mask = min(N_MASK, cfg.seq_len)
        pos = np.stack([
            rng.choice(cfg.seq_len, size=n_mask, replace=False)
            for _ in range(b)
        ]).astype(np.int32)
        labels = items[np.arange(b)[:, None], pos].copy()
        items2 = items.copy()
        items2[np.arange(b)[:, None], pos] = cfg.mask_id
        if n_mask < N_MASK:
            pad = N_MASK - n_mask
            pos = np.pad(pos, ((0, 0), (0, pad)))
            labels = np.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        out = {"items": jnp.asarray(items2), "mask_pos": jnp.asarray(pos),
               "mask_label": jnp.asarray(labels)}
    elif shape_name == "serve_bulk":
        out["pair_items"] = jnp.asarray(
            rng.integers(0, cfg.n_items, size=b), jnp.int32
        )
    elif shape_name == "retrieval_cand":
        out["candidate_ids"] = jnp.asarray(
            rng.integers(0, cfg.n_items, size=sh["n_candidates"]), jnp.int32
        )
    return out


def _pair_score(params, batch, cfg):
    hidden = R.bert4rec_encode(params, batch["items"], cfg)[:, -1]
    cand = params["item_embed"][jnp.clip(batch["pair_items"], 0, cfg.n_items - 1)]
    return jnp.sum(hidden * cand, axis=-1)


def _cand_score(params, batch, cfg):
    hidden = R.bert4rec_encode(params, batch["items"], cfg)[:, -1]  # (1, d)
    cand = params["item_embed"][jnp.clip(batch["candidate_ids"], 0, cfg.n_items - 1)]
    return hidden @ cand.T  # (1, C)


def cells() -> list[Cell]:
    out = []
    for shape_name, sh in RECSYS_SHAPES.items():
        kind = sh["kind"]
        if kind == "train":
            def make_step(cfg):
                return make_train_step(
                    lambda p, b, _cfg=cfg: R.bert4rec_loss(p, b, _cfg), OPT
                )
            donate = (0, 1)
        elif shape_name == "serve_p99":
            def make_step(cfg):
                def step(params, batch, _cfg=cfg):
                    return R.bert4rec_score(params, batch, _cfg)
                return step
            donate = ()
        elif shape_name == "serve_bulk":
            def make_step(cfg):
                def step(params, batch, _cfg=cfg):
                    return _pair_score(params, batch, _cfg)
                return step
            donate = ()
        else:  # retrieval_cand
            def make_step(cfg):
                def step(params, batch, _cfg=cfg):
                    return _cand_score(params, batch, _cfg)
                return step
            donate = ()
        out.append(_recsys_cell(
            "bert4rec", shape_name, CONFIG, SMOKE, kind, make_step,
            R.bert4rec_init,
            lambda cfg, s, _k=kind, _n=shape_name: _batch_struct(cfg, s, _k, _n),
            lambda cfg, s, rng, _k=kind, _n=shape_name: _make_batch(cfg, s, rng, _k, _n),
            donate=donate,
        ))
    return out
