"""qwen1.5-110b [dense] 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.configs.common import lm_cells
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen1.5-110b",
    vocab=152064,
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    qkv_bias=True,
    dtype="bfloat16",
    scan_unroll=1,    # scanned; dry-run corrects analysis w/ 2-point unroll probe
)

SMOKE = LMConfig(
    name="qwen1.5-110b-smoke",
    vocab=256, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
    qkv_bias=True, dtype="float32", kv_chunk=16,
)


def cells():
    return lm_cells("qwen1.5-110b", CONFIG, SMOKE)
