"""mind [recsys] embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest [arXiv:1904.08030]."""
import jax.numpy as jnp

from repro.configs.common import OPT, RECSYS_SHAPES, Cell, _recsys_cell, _sds
from repro.models import recsys as R
from repro.train.optimizer import make_train_step

CONFIG = R.MINDConfig(
    name="mind", n_items=1_000_000, embed_dim=64, n_interests=4,
    capsule_iters=3, seq_len=50,
)

SMOKE = R.MINDConfig(
    name="mind-smoke", n_items=128, embed_dim=16, n_interests=4,
    capsule_iters=3, seq_len=10,
)


def _batch_struct(cfg, sh, kind, shape_name):
    b = sh["batch"]
    out = {"items": _sds((b, cfg.seq_len), jnp.int32)}
    if kind == "train":
        out["target"] = _sds((b,), jnp.int32)
    elif shape_name == "serve_bulk":
        out["pair_items"] = _sds((b,), jnp.int32)
    elif shape_name == "retrieval_cand":
        out["candidate_ids"] = _sds((sh["n_candidates"],), jnp.int32)
    return out


def _make_batch(cfg, sh, rng, kind, shape_name):
    b = sh["batch"]
    out = {
        "items": jnp.asarray(
            rng.integers(0, cfg.n_items, size=(b, cfg.seq_len)), jnp.int32
        )
    }
    if kind == "train":
        out["target"] = jnp.asarray(rng.integers(0, cfg.n_items, size=b), jnp.int32)
    elif shape_name == "serve_bulk":
        out["pair_items"] = jnp.asarray(
            rng.integers(0, cfg.n_items, size=b), jnp.int32
        )
    elif shape_name == "retrieval_cand":
        out["candidate_ids"] = jnp.asarray(
            rng.integers(0, cfg.n_items, size=sh["n_candidates"]), jnp.int32
        )
    return out


def _pair_score(params, batch, cfg):
    """Bulk scoring: max over interests of capsule·item."""
    caps = R.mind_interests(params, batch["items"], cfg)  # (B, K, d)
    cand = params["item_embed"][jnp.clip(batch["pair_items"], 0, cfg.n_items - 1)]
    return jnp.max(jnp.einsum("bkd,bd->bk", caps, cand), axis=-1)


def _cand_score(params, batch, cfg):
    """Retrieval: every interest queries the 1M candidates; max-combine."""
    caps = R.mind_interests(params, batch["items"], cfg)  # (1, K, d)
    cand = params["item_embed"][jnp.clip(batch["candidate_ids"], 0, cfg.n_items - 1)]
    scores = jnp.einsum("bkd,cd->bkc", caps, cand)
    return jnp.max(scores, axis=1)  # (1, C)


def cells() -> list[Cell]:
    out = []
    for shape_name, sh in RECSYS_SHAPES.items():
        kind = sh["kind"]
        if kind == "train":
            def make_step(cfg):
                return make_train_step(
                    lambda p, b, _cfg=cfg: R.mind_loss(p, b, _cfg), OPT
                )
            donate = (0, 1)
        elif shape_name == "serve_p99":
            def make_step(cfg):
                def step(params, batch, _cfg=cfg):
                    return R.mind_serve(params, batch, _cfg)
                return step
            donate = ()
        elif shape_name == "serve_bulk":
            def make_step(cfg):
                def step(params, batch, _cfg=cfg):
                    return _pair_score(params, batch, _cfg)
                return step
            donate = ()
        else:
            def make_step(cfg):
                def step(params, batch, _cfg=cfg):
                    return _cand_score(params, batch, _cfg)
                return step
            donate = ()
        out.append(_recsys_cell(
            "mind", shape_name, CONFIG, SMOKE, kind, make_step,
            R.mind_init,
            lambda cfg, s, _k=kind, _n=shape_name: _batch_struct(cfg, s, _k, _n),
            lambda cfg, s, rng, _k=kind, _n=shape_name: _make_batch(cfg, s, rng, _k, _n),
            donate=donate,
        ))
    return out
