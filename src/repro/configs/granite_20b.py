"""granite-20b [dense] 52L d_model=6144 48H (GQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf]."""
from repro.configs.common import lm_cells
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="granite-20b",
    vocab=49152,
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,     # MQA (GQA kv=1)
    d_ff=24576,
    dtype="bfloat16",
    scan_unroll=1,    # scanned; dry-run corrects analysis w/ 2-point unroll probe
)

SMOKE = LMConfig(
    name="granite-20b-smoke",
    vocab=256, n_layers=2, d_model=64, n_heads=8, n_kv_heads=1, d_ff=128,
    dtype="float32", kv_chunk=16,
)


def cells():
    return lm_cells("granite-20b", CONFIG, SMOKE)
