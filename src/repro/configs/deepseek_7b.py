"""deepseek-7b [dense] 30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400 — llama-arch [arXiv:2401.02954; hf]."""
from repro.configs.common import lm_cells
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="deepseek-7b",
    vocab=102400,
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,    # full MHA (kv=32)
    d_ff=11008,
    dtype="bfloat16",
    scan_unroll=1,    # scanned; dry-run corrects analysis w/ 2-point unroll probe
)

SMOKE = LMConfig(
    name="deepseek-7b-smoke",
    vocab=256, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    dtype="float32", kv_chunk=16,
)


def cells():
    return lm_cells("deepseek-7b", CONFIG, SMOKE)
