"""granite-moe-1b-a400m [moe] 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.configs.common import lm_cells
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="granite-moe-1b-a400m",
    vocab=49155,
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    moe=True,
    n_experts=32,
    moe_top_k=8,
    dtype="bfloat16",
    scan_unroll=1,    # scanned; dry-run corrects analysis w/ 2-point unroll probe
)

SMOKE = LMConfig(
    name="granite-moe-smoke",
    vocab=256, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
    moe=True, n_experts=8, moe_top_k=2, dtype="float32", kv_chunk=16,
)


def cells():
    return lm_cells("granite-moe-1b-a400m", CONFIG, SMOKE)
