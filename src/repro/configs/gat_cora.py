"""gat-cora [gnn] n_layers=2 d_hidden=8 n_heads=8 aggregator=attn
[arXiv:1710.10903; paper].  The four shape cells swap the dataset geometry
(d_feat/classes per cell — see configs.common.GNN_SHAPES)."""
from repro.configs.common import gnn_cells
from repro.models.gnn import GATConfig

CONFIG = GATConfig(
    name="gat-cora",
    d_in=1433,
    d_hidden=8,
    n_heads=8,
    n_layers=2,
    n_classes=7,
)


def cells():
    return gnn_cells("gat-cora", CONFIG)
