"""Pytree dataclass helper.

We do not depend on flax/chex; this registers a plain ``dataclasses.dataclass``
as a JAX pytree.  Fields marked ``static=True`` become aux data (hashable,
compared by equality, trigger recompilation when changed) — used for shapes,
dtypes and protocol hyperparameters that must be compile-time constants.
"""
from __future__ import annotations

import dataclasses
from typing import Any, TypeVar

import jax

_T = TypeVar("_T")


def field(*, static: bool = False, **kwargs) -> Any:
    """Dataclass field; ``static=True`` marks it as pytree aux data."""
    metadata = dict(kwargs.pop("metadata", {}) or {})
    metadata["static"] = static
    return dataclasses.field(metadata=metadata, **kwargs)


def pytree_dataclass(cls: type[_T]) -> type[_T]:
    """Decorator: make ``cls`` a frozen dataclass registered as a pytree."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = dataclasses.fields(cls)
    data_names = [f.name for f in fields if not f.metadata.get("static", False)]
    static_names = [f.name for f in fields if f.metadata.get("static", False)]

    def flatten(obj):
        data = tuple(getattr(obj, n) for n in data_names)
        aux = tuple(getattr(obj, n) for n in static_names)
        return data, aux

    def flatten_with_keys(obj):
        data = tuple(
            (jax.tree_util.GetAttrKey(n), getattr(obj, n)) for n in data_names
        )
        aux = tuple(getattr(obj, n) for n in static_names)
        return data, aux

    def unflatten(aux, data):
        kwargs = dict(zip(data_names, data))
        kwargs.update(dict(zip(static_names, aux)))
        return cls(**kwargs)

    jax.tree_util.register_pytree_with_keys(
        cls, flatten_with_keys, unflatten, flatten
    )

    def replace(self, **updates):
        return dataclasses.replace(self, **updates)

    cls.replace = replace  # type: ignore[attr-defined]
    return cls
