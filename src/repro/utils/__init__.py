"""Small shared utilities (pytree dataclasses, logging, timing)."""
from repro.utils.tree import pytree_dataclass, field  # noqa: F401
