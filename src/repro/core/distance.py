"""Distance primitives shared by the whole index stack.

All SPFresh math assumes a Euclidean space (the NPA necessary-condition proofs
in paper §3.3 are Euclidean); squared L2 preserves the argmin/ordering so we
never take square roots on hot paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

# A value larger than any attainable squared distance for normalized data,
# used to mask out invalid centroids/slots.  Finite so top-k stays stable.
MASK_DISTANCE = jnp.float32(3.0e38)


def squared_norms(x: Array) -> Array:
    """Row-wise squared L2 norms, computed in f32."""
    xf = x.astype(jnp.float32)
    return jnp.sum(xf * xf, axis=-1)


def pairwise_sql2(q: Array, x: Array, x_sqn: Array | None = None) -> Array:
    """Pairwise squared-L2 distances ``(m, n)`` between ``q (m,d)`` and ``x (n,d)``.

    Uses the expansion ``‖q‖² − 2 qᵀx + ‖x‖²`` so the contraction runs on the
    MXU as a single GEMM.  Accumulation is f32 regardless of storage dtype.
    """
    qf = q.astype(jnp.float32)
    q_sqn = jnp.sum(qf * qf, axis=-1, keepdims=True)  # (m, 1)
    if x_sqn is None:
        x_sqn = squared_norms(x)
    cross = jax.lax.dot_general(
        qf,
        x.astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (m, n)
    d = q_sqn - 2.0 * cross + x_sqn[None, :]
    # Numerical guard: the expansion can go slightly negative.
    return jnp.maximum(d, 0.0)


def sql2(q: Array, x: Array) -> Array:
    """Squared L2 between broadcastable ``q (..., d)`` and ``x (..., d)``."""
    diff = q.astype(jnp.float32) - x.astype(jnp.float32)
    return jnp.sum(diff * diff, axis=-1)


def masked_topk(
    dists: Array, valid: Array, k: int
) -> tuple[Array, Array]:
    """Top-k *smallest* distances among ``valid`` entries.

    Returns ``(dists (..., k), indices (..., k))``.  Invalid entries get
    MASK_DISTANCE, so callers can detect "fewer than k valid" by comparing.
    """
    masked = jnp.where(valid, dists, MASK_DISTANCE)
    neg_d, idx = jax.lax.top_k(-masked, k)
    return -neg_d, idx
