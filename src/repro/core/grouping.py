"""Two-level centroid routing — the arithmetic-intensity-optimized
replacement for SPANN's SPTAG navigation graph (beyond-paper opt #1).

The flat navigator computes a (Q × P) distance GEMM over every posting
centroid.  At billion scale (P ≈ 65k/shard) that is ~90% of the search
FLOPs.  Two-level routing clusters the centroids into G balanced groups;
a query first scores the G group centroids, then scores only the members
of its ``gprobe`` nearest groups:

    FLOPs: Q·G·d + Q·gprobe·γ·d   vs   Q·P·d      (γ = group capacity)
    e.g. P=65536, G=256, γ=512, gprobe=8 → ~12× fewer navigation FLOPs.

Freshness: the group index is a *derived* structure rebuilt by the host at
the same cadence the paper updates its in-memory SPTAG index ("when the
background split and merge jobs are complete") — splits between refreshes
leave new centroids unrouted, which degrades recall gracefully until the
next refresh (measured in tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.clustering import balanced_kmeans
from repro.core.distance import MASK_DISTANCE, masked_topk, pairwise_sql2
from repro.core.types import IndexState
from repro.utils.tree import field, pytree_dataclass

Array = jax.Array


@pytree_dataclass
class GroupIndex:
    group_centroids: Array   # (G, d) f32
    group_sqn: Array         # (G,) f32
    members: Array           # (G, gamma) i32 posting ids, -1 empty
    member_valid: Array      # (G, gamma) bool


def build_group_index(
    state: IndexState, *, n_groups: int, capacity: int, seed: int = 0
) -> GroupIndex:
    """Cluster the valid posting centroids into ``n_groups`` balanced
    groups (host-driven; rebuilt after maintenance rounds)."""
    cen, assign = balanced_kmeans(
        jax.random.PRNGKey(seed),
        state.centroids,
        state.centroid_valid,
        k=n_groups,
        iters=10,
        balance_weight=2.0,
    )
    import numpy as np

    assign_np = np.asarray(assign)
    members = np.full((n_groups, capacity), -1, np.int32)
    counts = np.zeros(n_groups, np.int64)
    dropped = 0
    for pid in np.where(np.asarray(state.centroid_valid))[0]:
        g = assign_np[pid]
        if g < 0:
            continue
        if counts[g] < capacity:
            members[g, counts[g]] = pid
            counts[g] += 1
        else:
            # overflow: place in the least-full group (rare w/ balance)
            g2 = int(np.argmin(counts))
            if counts[g2] < capacity:
                members[g2, counts[g2]] = pid
                counts[g2] += 1
            else:
                dropped += 1
    assert dropped == 0, f"group capacity too small: {dropped} dropped"
    gm = jnp.asarray(members)
    cen = cen.astype(jnp.float32)
    return GroupIndex(
        group_centroids=cen,
        group_sqn=jnp.sum(cen * cen, axis=-1),
        members=gm,
        member_valid=gm >= 0,
    )


@functools.partial(jax.jit, static_argnames=("nprobe", "gprobe"))
def navigate_grouped(
    state: IndexState,
    gidx: GroupIndex,
    queries: Array,
    *,
    nprobe: int,
    gprobe: int,
) -> tuple[Array, Array]:
    """Two-level nearest-``nprobe`` postings.  Same interface as
    ``lire.navigate``; exact when gprobe == n_groups."""
    q = queries.shape[0]
    gamma = gidx.members.shape[1]

    # level 1: route to gprobe nearest groups
    dg = pairwise_sql2(queries, gidx.group_centroids, gidx.group_sqn)
    any_member = jnp.any(gidx.member_valid, axis=1)
    _, top_g = masked_topk(dg, any_member[None, :], gprobe)  # (Q, gprobe)

    # level 2: exact distances to the members of those groups
    cand = gidx.members[jnp.maximum(top_g, 0)]        # (Q, gprobe, gamma)
    cand_valid = gidx.member_valid[jnp.maximum(top_g, 0)] & (top_g >= 0)[..., None]
    cand = cand.reshape(q, gprobe * gamma)
    cand_valid = cand_valid.reshape(q, gprobe * gamma)
    safe = jnp.maximum(cand, 0)
    c = state.centroids[safe]                         # (Q, gprobe*gamma, d)
    qf = queries.astype(jnp.float32)
    diff = qf[:, None, :] - c.astype(jnp.float32)
    d = jnp.sum(diff * diff, axis=-1)
    live = cand_valid & state.centroid_valid[safe]
    d = jnp.where(live, d, MASK_DISTANCE)
    top_d, sel = jax.lax.top_k(-d, nprobe)
    top_d = -top_d
    pids = jnp.take_along_axis(cand, sel, axis=1)
    pids = jnp.where(top_d < MASK_DISTANCE / 2, pids, -1)
    return top_d, pids


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "nprobe", "gprobe", "probe_chunk", "use_pallas_scan",
        "scan_schedule",
    ),
)
def search_grouped(
    state: IndexState,
    gidx: GroupIndex,
    queries: Array,
    *,
    k: int,
    nprobe: int | None = None,
    gprobe: int = 8,
    probe_chunk: int = 0,
    use_pallas_scan: bool | None = None,
    scan_schedule: str | None = None,
) -> tuple[Array, Array]:
    """lire.search with two-level navigation.  The scan + reduce is the
    shared ``lire.scan_and_reduce`` data path, so the Pallas paged scan,
    the batch-dedup schedule, and probe chunking all apply here too."""
    from repro.core import lire

    cfg = state.cfg
    nprobe = nprobe or cfg.nprobe
    nav_d, pids = navigate_grouped(
        state, gidx, queries, nprobe=nprobe, gprobe=gprobe
    )
    probe_valid = nav_d < MASK_DISTANCE / 2
    return lire.scan_and_reduce(
        state, queries, pids, probe_valid,
        k=k, probe_chunk=probe_chunk,
        use_pallas_scan=use_pallas_scan, scan_schedule=scan_schedule,
    )
