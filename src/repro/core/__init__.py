"""SPFresh core: LIRE protocol, SPANN-style index, NPA conditions."""
from repro.core.index import SPFreshIndex, build_state  # noqa: F401
from repro.core.types import IndexState, LireConfig, make_empty_state  # noqa: F401
