"""SPFreshIndex — the user-facing index object.

Composition (paper Fig. 5):
  * offline build      — SPANN hierarchical balanced clustering + closure
                         replication (host-driven, §3.1);
  * foreground Updater — `insert`/`delete` (jitted `lire.insert_batch` /
                         `lire.delete_batch`), WAL-logged;
  * background Local Rebuilder — `maintain()` drains split/merge/reassign
                         jobs in batched rounds (jitted
                         `lire.maintenance_round`);
  * Searcher           — `search()`;
  * crash recovery     — `snapshot()` / `restore()` = snapshot + WAL replay.

The wrapper is a thin *host* convenience: all state transitions are the
functional ops in `repro.core.lire`; distributed execution wraps those same
ops in shard_map (see `repro.distributed.sharded_index`).
"""
from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lire
from repro.core.clustering import hierarchical_balanced_kmeans
from repro.core.distance import pairwise_sql2
from repro.core.types import IndexState, LireConfig, make_empty_state
from repro.storage import codec as pcodec
from repro.storage.snapshot import load_snapshot, save_snapshot, snapshot_exists
from repro.storage.wal import WriteAheadLog, iter_wal

_INSERT_CHUNK = 256
_QUERY_CHUNK = 64


def _build_routing(
    vectors: np.ndarray,
    centroids: np.ndarray,
    assign: np.ndarray,
    cfg: LireConfig,
    chunk: int = 8192,
) -> list[list[int]]:
    """Vector → posting membership lists: primary (from the clustering) plus
    SPANN closure replicas (top-R centroids within the replica_rng ratio)."""
    n = vectors.shape[0]
    p = centroids.shape[0]
    members: list[list[int]] = [[] for _ in range(p)]
    for i in range(n):
        members[int(assign[i])].append(i)

    if cfg.replica_count > 1 and p > 1:
        r = min(cfg.replica_count, p)
        cen = jnp.asarray(centroids, jnp.float32)
        factor = float(cfg.replica_rng) ** 2
        cap = cfg.posting_capacity
        for start in range(0, n, chunk):
            xs = jnp.asarray(vectors[start : start + chunk], jnp.float32)
            d = pairwise_sql2(xs, cen)
            neg_d, idx = jax.lax.top_k(-d, r)
            dists = np.asarray(-neg_d)
            idx = np.asarray(idx)
            for row in range(idx.shape[0]):
                vid = start + row
                dmin = dists[row, 0]
                for j in range(r):
                    pid = int(idx[row, j])
                    if pid == int(assign[vid]):
                        continue
                    if dists[row, j] <= factor * dmin and len(members[pid]) < cap:
                        members[pid].append(vid)
    return members


def build_state(
    cfg: LireConfig,
    vectors: np.ndarray,
    *,
    seed: int = 0,
    build_posting_size: int | None = None,
) -> IndexState:
    """Offline SPANN-style build → a ready IndexState (host-constructed)."""
    cfg.validate()
    vectors = np.asarray(vectors, np.float32)
    n, d = vectors.shape
    assert d == cfg.dim, (d, cfg.dim)
    assert n <= cfg.num_vectors_cap

    target = build_posting_size or max(cfg.merge_limit + 1, int(cfg.split_limit * 0.6))
    centroids, assign = hierarchical_balanced_kmeans(
        vectors, max_posting_size=target, seed=seed
    )
    p = centroids.shape[0]
    if p > cfg.num_postings_cap:
        raise ValueError(
            f"build produced {p} postings > cap {cfg.num_postings_cap}; "
            "raise num_postings_cap or split_limit"
        )
    members = _build_routing(vectors, centroids, assign, cfg)

    bs, mb = cfg.block_size, cfg.max_blocks_per_posting
    cap = cfg.posting_capacity
    quant = cfg.codec == "int8"
    # hot tier staged at fp32 for fp32/bf16 (converted to the payload dtype
    # below); int8 encodes per posting during the fill
    blocks = np.zeros(
        (cfg.num_blocks, bs, d),
        np.int8 if quant else np.dtype(cfg.vector_dtype),
    )
    exact = (
        np.zeros((cfg.num_blocks, bs, d), np.float32)
        if pcodec.has_exact_tier(cfg.codec)
        else None
    )
    post_scale = np.ones((cfg.num_postings_cap,), np.float32)
    post_zero = np.zeros((cfg.num_postings_cap,), np.float32)
    block_vid = np.full((cfg.num_blocks, bs), -1, np.int32)
    block_ver = np.zeros((cfg.num_blocks, bs), np.uint8)
    posting_blocks = np.full((cfg.num_postings_cap, mb), -1, np.int32)
    posting_len = np.zeros((cfg.num_postings_cap,), np.int32)

    next_block = 0
    for pid in range(p):
        mem = members[pid][:cap]
        posting_len[pid] = len(mem)
        nb = math.ceil(len(mem) / bs) if mem else 0
        if next_block + nb > cfg.num_blocks:
            raise ValueError("num_blocks too small for the build")
        if mem:
            scale, zero = pcodec.np_train_scale_zero(vectors[mem])
            post_scale[pid] = scale
            post_zero[pid] = zero
        for b in range(nb):
            bid = next_block
            next_block += 1
            posting_blocks[pid, b] = bid
            rows = mem[b * bs : (b + 1) * bs]
            raw = vectors[rows]
            blocks[bid, : len(rows)] = (
                pcodec.np_encode(raw, post_scale[pid], post_zero[pid])
                if quant
                else raw
            )
            if exact is not None:
                exact[bid, : len(rows)] = raw
            block_vid[bid, : len(rows)] = rows

    state = make_empty_state(cfg, seed=seed)
    # free block stack: unused blocks
    free_blocks = np.arange(next_block, cfg.num_blocks, dtype=np.int32)
    free_stack = np.zeros((cfg.num_blocks,), np.int32)
    free_stack[: free_blocks.size] = free_blocks
    # free pid stack: unused pids
    free_pids = np.arange(p, cfg.num_postings_cap, dtype=np.int32)
    pid_stack = np.zeros((cfg.num_postings_cap,), np.int32)
    pid_stack[: free_pids.size] = free_pids

    cen = np.zeros((cfg.num_postings_cap, d), np.float32)
    cen[:p] = centroids
    cvalid = np.zeros((cfg.num_postings_cap,), bool)
    cvalid[:p] = True

    pool = state.pool.replace(
        blocks=jnp.asarray(blocks).astype(state.pool.blocks.dtype),
        blocks_exact=(
            jnp.asarray(exact) if exact is not None else None
        ),
        block_vid=jnp.asarray(block_vid),
        block_ver=jnp.asarray(block_ver),
        posting_blocks=jnp.asarray(posting_blocks),
        posting_len=jnp.asarray(posting_len),
        free_stack=jnp.asarray(free_stack),
        free_top=jnp.asarray(free_blocks.size, jnp.int32),
        post_scale=jnp.asarray(post_scale),
        post_zero=jnp.asarray(post_zero),
    )
    return state.replace(
        pool=pool,
        centroids=jnp.asarray(cen),
        centroid_sqn=jnp.asarray(np.sum(cen * cen, axis=-1)),
        centroid_valid=jnp.asarray(cvalid),
        pid_free_stack=jnp.asarray(pid_stack),
        pid_free_top=jnp.asarray(free_pids.size, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Batched jit entry points (the serving pipeline's hot path)
#
# The ServeEngine feeds fixed-shape padded micro-batches straight into these
# cached executables — no host-side chunking loop, one dispatch per batch.
# Update steps donate the index state so XLA can mutate the (large) block
# pool in place instead of copying it every batch.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def search_step(
    k: int,
    nprobe: int | None,
    probe_chunk: int = 0,
    use_pallas_scan: bool | None = None,
    scan_schedule: str | None = None,
    with_access: bool = False,
):
    """jitted ``(state, queries (B, d)) -> (dists (B, k), vids (B, k))``.

    ``probe_chunk`` / ``use_pallas_scan`` / ``scan_schedule`` select the
    posting-scan data path (None defers to the state's config flags) —
    the serving pipeline threads them through from ``EngineConfig``.
    ``with_access`` adds the per-posting probe histogram as a third
    output (the serving backend's access-telemetry source).
    """
    return jax.jit(
        functools.partial(
            lire.search, k=k, nprobe=nprobe, probe_chunk=probe_chunk,
            use_pallas_scan=use_pallas_scan, scan_schedule=scan_schedule,
            with_access=with_access,
        )
    )


@functools.lru_cache(maxsize=None)
def insert_step():
    """jitted, state-donating ``(state, vecs, vids, valid) -> (state, landed)``."""

    def f(state, vecs, vids, valid):
        return lire.insert_batch(state, vecs, vids, valid)

    return jax.jit(f, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def delete_step():
    """jitted, state-donating ``(state, vids, valid) -> state``."""

    def f(state, vids, valid):
        return lire.delete_batch(state, vids, valid)

    return jax.jit(f, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def fused_maintenance_step(budget: int):
    """jitted, state-donating SEQUENTIAL rebuilder slot: ``budget``
    one-job-at-a-time maintenance steps in ONE executable (a lax.scan),
    returning ``(state, n_did_work)``.

    Kept as the baseline the batched round is benchmarked against
    (`benchmarks/bench_maintenance.py`); the serving pipeline dispatches
    `fused_maintenance_round` instead."""

    def f(state):
        def body(s, _):
            s, did = lire.maintenance_step(s)
            return s, did.astype(jnp.int32)

        state, dids = jax.lax.scan(body, state, None, length=budget)
        return state, jnp.sum(dids)

    return jax.jit(f, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def fused_maintenance_round(jobs: int):
    """jitted, state-donating batched rebuilder round: the top-``jobs``
    oversized postings split and bottom-``jobs`` undersized merged in ONE
    executable with a single fused reassignment pass, returning
    ``(state, n_jobs_done)``.

    Constant work regardless of how many jobs fire — the TPU idiom for the
    paper's background job queue; the host pays one dispatch and reads one
    did-work scalar per round.  The second operand is the (P_cap,) i32
    access histogram folded into the telemetry before job selection (all
    zeros when the caller has none — an exact no-op fold)."""

    def f(state, access):
        return lire.maintenance_round(state, jobs, access)

    return jax.jit(f, donate_argnums=(0,))


def _pad_to(x: np.ndarray, size: int, fill=0) -> np.ndarray:
    pad = size - x.shape[0]
    if pad <= 0:
        return x
    width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, width, constant_values=fill)


class SPFreshIndex:
    """Stateful host wrapper over the functional LIRE ops."""

    def __init__(self, state: IndexState, wal_path: str | None = None):
        self.state = state
        self.wal = WriteAheadLog(wal_path) if wal_path else None
        self._wal_applied = self.wal.next_seqno - 1 if self.wal else -1
        self.last_drain_rounds = 0

    # ------------------------------------------------------------------
    @classmethod
    def build(
        cls,
        cfg: LireConfig,
        vectors: np.ndarray,
        *,
        seed: int = 0,
        wal_path: str | None = None,
    ) -> "SPFreshIndex":
        return cls(build_state(cfg, vectors, seed=seed), wal_path=wal_path)

    # ---------------------------- Updater -----------------------------
    def insert(
        self,
        vecs: np.ndarray,
        vids: np.ndarray,
        *,
        log: bool = True,
        max_retries: int = 4,
    ) -> None:
        """Foreground insert with pipeline backpressure.

        When a primary append hits a posting at hard capacity, we run the
        Local Rebuilder (which splits the oversized posting) and retry the
        unlanded vectors — the explicit-backpressure form of the paper's
        Updater→Rebuilder feed-forward pipeline.
        """
        vecs = np.asarray(vecs, np.float32)
        vids = np.asarray(vids, np.int32)
        if log and self.wal is not None:
            self._wal_applied = self.wal.append(
                "insert", {"vecs": vecs, "vids": vids}
            )
        for s in range(0, len(vids), _INSERT_CHUNK):
            v = vecs[s : s + _INSERT_CHUNK]
            i = vids[s : s + _INSERT_CHUNK]
            for attempt in range(max_retries + 1):
                nvalid = len(i)
                if nvalid == 0:
                    break
                vp = _pad_to(v, _INSERT_CHUNK)
                ip = _pad_to(i, _INSERT_CHUNK, fill=-1)
                valid = np.arange(_INSERT_CHUNK) < nvalid
                self.state, landed = lire.insert_batch(
                    self.state, jnp.asarray(vp), jnp.asarray(ip), jnp.asarray(valid)
                )
                landed = np.asarray(landed)[:nvalid]
                if landed.all() or attempt == max_retries:
                    break
                # Backpressure: let the rebuilder split the full posting(s).
                self.maintain()
                v, i = v[~landed], i[~landed]

    def delete(self, vids: np.ndarray, *, log: bool = True) -> None:
        vids = np.asarray(vids, np.int32)
        if log and self.wal is not None:
            self._wal_applied = self.wal.append("delete", {"vids": vids})
        for s in range(0, len(vids), _INSERT_CHUNK):
            i = vids[s : s + _INSERT_CHUNK]
            nvalid = len(i)
            i = _pad_to(i, _INSERT_CHUNK, fill=-1)
            valid = np.arange(_INSERT_CHUNK) < nvalid
            self.state = lire.delete_batch(
                self.state, jnp.asarray(i), jnp.asarray(valid)
            )

    # ------------------------- Local Rebuilder -------------------------
    def maintain(
        self, max_steps: int | None = None, jobs_per_round: int | None = None,
        access: np.ndarray | None = None,
    ) -> int:
        """Drain split/merge/reassign jobs in batched rounds (one did-work
        readback per round); returns jobs executed.  ``jobs_per_round``
        defaults to ``cfg.jobs_per_round``; the round count of the last
        drain is kept in ``last_drain_rounds``.  ``access`` (optional
        probe histogram) folds into the first round's selection."""
        self.state, jobs, rounds = lire.rebuild_drain(
            self.state, max_steps, jobs_per_round, donate=True, access=access
        )
        self.last_drain_rounds = rounds
        return jobs

    # ---------------------------- Searcher -----------------------------
    def search(
        self, queries: np.ndarray, k: int, *, nprobe: int | None = None,
        probe_chunk: int = 0, use_pallas_scan: bool | None = None,
        scan_schedule: str | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        queries = np.asarray(queries, np.float32)
        nq = queries.shape[0]
        out_d, out_v = [], []
        for s in range(0, nq, _QUERY_CHUNK):
            q = _pad_to(queries[s : s + _QUERY_CHUNK], _QUERY_CHUNK)
            d, v = lire.search(
                self.state, jnp.asarray(q), k=k,
                nprobe=nprobe or self.state.cfg.nprobe,
                probe_chunk=probe_chunk, use_pallas_scan=use_pallas_scan,
                scan_schedule=scan_schedule,
            )
            out_d.append(np.asarray(d))
            out_v.append(np.asarray(v))
        d = np.concatenate(out_d)[:nq]
        v = np.concatenate(out_v)[:nq]
        return d, v

    # ------------------- Batched pipeline entry points -----------------
    # Fixed-shape, one-dispatch variants driven by the ServeEngine; the
    # caller (the RequestQueue) owns padding and bucket discipline.

    def search_padded(
        self, queries: np.ndarray, k: int, *, nprobe: int | None = None,
        probe_chunk: int = 0, use_pallas_scan: bool | None = None,
        scan_schedule: str | None = None, with_access: bool = False,
        qvalid: np.ndarray | None = None, as_jax: bool = False,
    ) -> tuple[np.ndarray, ...]:
        """One fixed-shape search dispatch.  ``as_jax=True`` returns the
        raw device arrays without forcing a host readback — the dispatch
        is already in flight (JAX async dispatch), so the caller can
        overlap device work with other host/device activity and convert
        with ``np.asarray`` at scatter time."""
        step = search_step(
            k, nprobe, probe_chunk, use_pallas_scan, scan_schedule,
            with_access,
        )
        if qvalid is None:
            out = step(self.state, jnp.asarray(queries))
        else:
            out = step(
                self.state, jnp.asarray(queries),
                qvalid=jnp.asarray(qvalid, bool),
            )
        if as_jax:
            return tuple(out)
        return tuple(np.asarray(x) for x in out)

    def insert_padded(
        self, vecs: np.ndarray, vids: np.ndarray, valid: np.ndarray,
    ) -> np.ndarray:
        """One donated-state insert dispatch; returns the landed mask."""
        self.state, landed = insert_step()(
            self.state, jnp.asarray(vecs), jnp.asarray(vids),
            jnp.asarray(valid),
        )
        return np.asarray(landed)

    def delete_padded(self, vids: np.ndarray, valid: np.ndarray) -> None:
        self.state = delete_step()(
            self.state, jnp.asarray(vids), jnp.asarray(valid)
        )

    def maintain_round(
        self, jobs: int | None = None, access: np.ndarray | None = None,
    ) -> int:
        """One fused rebuilder round (``jobs`` split+merge jobs + one
        fused reassign pass, one dispatch); returns how many jobs acted.
        ``access`` is the serving backend's pending probe histogram
        (None folds zeros — an exact no-op)."""
        jobs = jobs or self.state.cfg.jobs_per_round
        if access is None:
            access = np.zeros(
                (self.state.cfg.num_postings_cap,), np.int32
            )
        self.state, did = fused_maintenance_round(jobs)(
            self.state, jnp.asarray(access, jnp.int32)
        )
        return int(did)

    # Pre-round name for the one-dispatch maintenance slot; the budget is
    # now a jobs-per-round count.
    maintain_fused = maintain_round

    def maintain_fused_seq(self, budget: int) -> int:
        """One SEQUENTIAL fused slot (``budget`` one-job steps, one
        dispatch) — the benchmark baseline for the batched round."""
        self.state, did = fused_maintenance_step(budget)(self.state)
        return int(did)

    def backlog(self) -> int:
        """Rebuild backlog: postings currently over the split limit."""
        lens = np.asarray(self.state.pool.posting_len)
        valid = np.asarray(self.state.centroid_valid)
        return int(((lens > self.state.cfg.split_limit) & valid).sum())

    # ------------------------- Crash recovery --------------------------
    def snapshot(self, path: str) -> None:
        save_snapshot(
            path, self.state, extra={"wal_seqno": self._wal_applied}
        )
        if self.wal is not None:
            self.wal.truncate()

    @classmethod
    def restore(
        cls,
        path: str,
        cfg: LireConfig,
        *,
        wal_path: str | None = None,
    ) -> "SPFreshIndex":
        """Latest snapshot + WAL replay (paper §4.4)."""
        template = make_empty_state(cfg)
        if snapshot_exists(path):
            state, manifest = load_snapshot(path, template)
            after = manifest["extra"].get("wal_seqno", -1)
        else:
            state, after = template, -1
        idx = cls.__new__(cls)
        idx.state = state
        idx.wal = None
        idx._wal_applied = after
        idx.last_drain_rounds = 0
        if wal_path and os.path.exists(wal_path):
            for rec in iter_wal(wal_path, after_seqno=after):
                if rec.op == "insert":
                    idx.insert(rec.payload["vecs"], rec.payload["vids"], log=False)
                elif rec.op == "delete":
                    idx.delete(rec.payload["vids"], log=False)
                idx._wal_applied = rec.seqno
        if wal_path:
            idx.wal = WriteAheadLog(wal_path)
        return idx

    # ---------------------------- Accounting ---------------------------
    def stats(self) -> dict:
        s = self.state.stats
        out = {
            k: int(getattr(s, k))
            for k in (
                "n_inserts", "n_deletes", "n_appends", "n_append_drops",
                "n_splits", "n_gc_writebacks", "n_merges",
                "n_reassign_checked", "n_reassign_candidates",
                "n_reassigned", "n_reassign_overflow",
            )
        }
        out["n_postings"] = int(self.state.n_postings)
        out["used_blocks"] = int(
            self.state.pool.num_blocks_cap - self.state.pool.free_top
        )
        # Telemetry aggregates read the STATE leaves only — never the
        # serving backend's host-side pending-access buffer — so two
        # services whose WALs replayed identically report identical stats.
        tel = self.state.telemetry
        valid = np.asarray(self.state.centroid_valid)
        out["access_total"] = int(np.asarray(tel.access_count)[valid].sum())
        out["update_total"] = int(np.asarray(tel.update_count)[valid].sum())
        out["drift_norm_total"] = float(
            np.linalg.norm(
                np.asarray(tel.drift_vec)[valid], axis=-1
            ).sum()
        )
        return out

    def memory_bytes(self) -> dict:
        """Resource accounting analogous to paper Fig. 7(d): what must sit in
        'DRAM' (centroids + mappings + versions) vs 'disk' (block payloads).

        ``hot`` is the scan-path payload (codec dtype + per-posting quant
        params); ``cold`` the exact tier a lossy codec carries; ``disk``
        their sum plus slot metadata."""
        st = self.state
        in_mem = (
            st.centroids.size * 4
            + st.centroid_sqn.size * 4
            + st.centroid_valid.size
            + st.versions.size
            + st.pool.posting_blocks.size * 4
            + st.pool.posting_len.size * 4
            + st.pool.free_stack.size * 4
            + st.pid_free_stack.size * 4
        )
        hot = (
            st.pool.blocks.size * st.pool.blocks.dtype.itemsize
            + st.pool.post_scale.size * 4
            + st.pool.post_zero.size * 4
        )
        cold = (
            st.pool.blocks_exact.size * st.pool.blocks_exact.dtype.itemsize
            if st.pool.blocks_exact is not None
            else 0
        )
        on_disk = (
            hot
            + cold
            + st.pool.block_vid.size * 4
            + st.pool.block_ver.size
        )
        return {"memory": in_mem, "disk": on_disk, "hot": hot, "cold": cold}
