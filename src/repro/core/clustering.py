"""Balanced clustering: SPANN index build + LIRE split primitive.

Two entry points:

* :func:`balanced_kmeans` — fixed-iteration Lloyd with a size-penalty term,
  the JAX adaptation of SPANN's multi-constraint balanced clustering [67].
  Fully jittable (fixed shapes, ``fori_loop``), supports a validity mask so
  it can run over fixed-capacity posting buffers.
* :func:`hierarchical_balanced_kmeans` — host-driven recursive splitter used
  for the *offline* index build: split until every leaf fits
  ``max_posting_size``, returning centroids + assignments.  The per-node work
  is the jitted :func:`balanced_kmeans`; the recursion is host-side because
  build is offline (paper builds the base index offline too).

The LIRE *split* op uses ``balanced_kmeans(k=2)`` — the paper's "multi-
constraint balanced clustering ... to generate high-quality centroids and
balanced postings" (§4.2.1) specialized to a single oversized posting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distance import MASK_DISTANCE, pairwise_sql2

Array = jax.Array


@functools.partial(
    jax.jit, static_argnames=("k", "iters", "balance_weight")
)
def balanced_kmeans(
    key: Array,
    x: Array,
    valid: Array,
    *,
    k: int,
    iters: int = 10,
    balance_weight: float = 1.0,
) -> tuple[Array, Array]:
    """Size-penalized Lloyd over the ``valid`` rows of ``x (n, d)``.

    Assignment cost for cluster c is ``sql2(x, centroid_c) + λ·size_c·mean_d``
    where ``size_c`` is the running cluster size from the previous iteration
    (SPANN's balance constraint as a Lagrangian penalty; λ=balance_weight).

    Returns ``(centroids (k, d) f32, assign (n,) i32)``; invalid rows get
    assignment ``-1``.
    """
    n, d = x.shape
    xf = x.astype(jnp.float32)
    validf = valid.astype(jnp.float32)
    n_valid = jnp.maximum(jnp.sum(validf), 1.0)

    # Init: k distinct valid points (gumbel-top-k over the validity mask).
    g = jax.random.gumbel(key, (n,))
    scores = jnp.where(valid, g, -jnp.inf)
    _, init_idx = jax.lax.top_k(scores, k)
    centroids0 = xf[init_idx]

    # Mean pairwise scale for the penalty: use mean squared norm spread.
    mean_sq = jnp.sum(jnp.sum(xf * xf, axis=-1) * validf) / n_valid

    def assign_step(centroids, sizes):
        dists = pairwise_sql2(xf, centroids)  # (n, k)
        penalty = balance_weight * (sizes / n_valid) * (mean_sq + 1e-6)
        cost = dists + penalty[None, :]
        a = jnp.argmin(cost, axis=-1).astype(jnp.int32)
        return jnp.where(valid, a, -1)

    def update_centroids(assign, centroids):
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # (-1 -> zeros)
        counts = jnp.sum(onehot, axis=0)  # (k,)
        sums = jnp.einsum("nk,nd->kd", onehot, xf)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # Keep old centroid if a cluster emptied out.
        new = jnp.where((counts > 0)[:, None], new, centroids)
        return new, counts

    def body(_, carry):
        centroids, sizes = carry
        a = assign_step(centroids, sizes)
        centroids, counts = update_centroids(a, centroids)
        return centroids, counts

    init_sizes = jnp.zeros((k,), jnp.float32)
    centroids, sizes = jax.lax.fori_loop(
        0, iters, body, (centroids0, init_sizes)
    )
    assign = assign_step(centroids, sizes)
    # Final centroid refresh so returned centroids match the assignment.
    centroids, _ = update_centroids(assign, centroids)
    return centroids, assign


def hierarchical_balanced_kmeans(
    x: np.ndarray,
    *,
    max_posting_size: int,
    branch: int = 8,
    iters: int = 10,
    balance_weight: float = 1.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Offline SPANN-style build: recursively split until every leaf fits.

    Returns ``(centroids (P, d) f32, assign (n,) i32)`` with
    ``max leaf size <= max_posting_size`` (up to degenerate duplicates).
    Host-driven recursion over jitted :func:`balanced_kmeans`.
    """
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    assign = np.zeros((n,), np.int32)
    centroids: list[np.ndarray] = []
    key = jax.random.PRNGKey(seed)

    # Work stack of index arrays into x.
    stack: list[np.ndarray] = [np.arange(n, dtype=np.int64)]
    guard = 0
    while stack:
        guard += 1
        if guard > 16 * max(1, n // max(1, max_posting_size)) + 64:
            # Degenerate data (e.g. all-identical points): stop splitting.
            for idx in stack:
                cid = len(centroids)
                centroids.append(x[idx].mean(axis=0))
                assign[idx] = cid
            break
        idx = stack.pop()
        if idx.size <= max_posting_size:
            cid = len(centroids)
            centroids.append(
                x[idx].mean(axis=0) if idx.size else np.zeros(x.shape[1], np.float32)
            )
            assign[idx] = cid
            continue
        k = min(branch, max(2, int(np.ceil(idx.size / max_posting_size))))
        key, sub = jax.random.split(key)
        sub_x = jnp.asarray(x[idx])
        valid = jnp.ones((idx.size,), bool)
        _, a = balanced_kmeans(
            sub, sub_x, valid, k=k, iters=iters, balance_weight=balance_weight
        )
        a = np.asarray(a)
        split_happened = False
        for c in range(k):
            child = idx[a == c]
            if child.size == 0:
                continue
            if child.size < idx.size:
                split_happened = True
            stack.append(child)
        if not split_happened:
            # k-means failed to split (identical points): force halve.
            stack.pop()  # remove the re-pushed full set
            half = idx.size // 2
            stack.append(idx[:half])
            stack.append(idx[half:])
    return np.stack(centroids, axis=0), assign


@functools.partial(jax.jit, static_argnames=("iters",))
def balanced_two_means(
    key: Array, x: Array, valid: Array, *, iters: int = 8
) -> tuple[Array, Array]:
    """LIRE split primitive: balanced 2-means over a posting buffer.

    ``x (L, d)`` is the (garbage-collected) posting contents with validity
    mask ``valid (L,)``.  Returns ``(centroids (2, d), assign (L,) in
    {-1,0,1})``.  Balance is enforced *hard* at the end: if one side exceeds
    ``ceil(n_valid/2) + slack`` the farthest-from-centroid excess vectors are
    flipped, matching the paper's "evenly splits the oversized posting into
    two smaller ones" (§3.2).
    """
    L, d = x.shape
    centroids, assign = balanced_kmeans(
        key, x, valid, k=2, iters=iters, balance_weight=2.0
    )
    # Hard rebalance: compute signed preference and flip the worst offenders.
    xf = x.astype(jnp.float32)
    d0 = jnp.sum((xf - centroids[0]) ** 2, axis=-1)
    d1 = jnp.sum((xf - centroids[1]) ** 2, axis=-1)
    pref = d0 - d1  # >0 means prefers cluster 1
    a = jnp.where(pref > 0, 1, 0).astype(jnp.int32)
    a = jnp.where(valid, a, -1)
    n_valid = jnp.sum(valid)
    target = (n_valid + 1) // 2

    def flip_excess(a):
        n1 = jnp.sum(a == 1)
        n0 = jnp.sum(a == 0)
        # margin of moving to the other side; flip smallest margins first.
        margin = jnp.abs(pref)
        # excess on side 1 -> flip to 0 those with smallest margin.
        def flip(a, from_side, count):
            cand = (a == from_side)
            score = jnp.where(cand, -margin, -jnp.inf)
            # top-|count| smallest margins among cand
            order = jnp.argsort(-score)  # descending score = ascending margin
            ranks = jnp.zeros((L,), jnp.int32).at[order].set(
                jnp.arange(L, dtype=jnp.int32)
            )
            to_flip = cand & (ranks < count)
            return jnp.where(to_flip, 1 - from_side, a)

        a = jax.lax.cond(
            n1 > target, lambda a: flip(a, 1, n1 - target), lambda a: a, a
        )
        n0 = jnp.sum(a == 0)
        a = jax.lax.cond(
            n0 > target, lambda a: flip(a, 0, n0 - target), lambda a: a, a
        )
        return a

    a = flip_excess(a)
    # Refresh centroids to match the final assignment.
    onehot = jax.nn.one_hot(a, 2, dtype=jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    sums = jnp.einsum("nk,nd->kd", onehot, xf)
    centroids = sums / jnp.maximum(counts, 1.0)[:, None]
    return centroids, a
