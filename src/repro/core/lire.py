"""LIRE protocol operations — paper §3 + §4.2.

External interface: :func:`insert_batch`, :func:`delete_batch`,
:func:`search`.  Internal (Local Rebuilder): :func:`split_posting`,
:func:`merge_posting`, :func:`maintenance_step`.

Every op is a jittable, fixed-shape functional state transition.  Branchy
protocol logic is expressed with ``enable`` masks threaded through the
storage ops, so a maintenance step is constant work regardless of whether a
job fires (the TPU idiom for the paper's background job queue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import npa
from repro.core.clustering import balanced_two_means
from repro.core.distance import MASK_DISTANCE, masked_topk, pairwise_sql2, sql2
from repro.core.types import (
    IndexState,
    LireStats,
    alloc_pid,
    bump_stat,
    free_pid,
    set_centroid,
)
from repro.kernels.posting_scan import ops as scan_ops
from repro.storage import blockpool as bp
from repro.storage import versionmap as vm

Array = jax.Array


# ---------------------------------------------------------------------------
# Centroid navigation (the SPTAG replacement: dense GEMM + top-k)
# ---------------------------------------------------------------------------

def navigate(state: IndexState, queries: Array, nprobe: int) -> tuple[Array, Array]:
    """Nearest-``nprobe`` valid posting centroids for each query.

    Returns ``(dists (Q, nprobe), pids (Q, nprobe))``; invalid slots have
    MASK_DISTANCE.  With ``cfg.use_pallas_nav`` the fused Pallas ``l2_topk``
    kernel runs (TPU target; interpret mode on CPU); the pure-XLA GEMM +
    masked top-k below is the oracle and the default CPU path.
    """
    if state.cfg.use_pallas_nav:
        from repro.kernels.l2_topk.ops import l2_topk

        d, idx = l2_topk(
            queries, state.centroids, state.centroid_valid, k=nprobe,
            interpret=state.cfg.pallas_interpret,
        )
        d = jnp.where(idx >= 0, d, MASK_DISTANCE)
        return d, idx
    d = pairwise_sql2(queries, state.centroids, state.centroid_sqn)
    return masked_topk(d, state.centroid_valid[None, :], nprobe)


def route(
    state: IndexState, vecs: Array, r: int
) -> tuple[Array, Array, Array]:
    """Insert/reassign routing: top-``r`` centroids + closure-replica mask.

    A vector is replicated into posting ``i`` iff
    ``d_i <= replica_rng^2 * d_min`` (SPANN closure rule, squared-L2 form).
    Returns ``(pids (B, r), dists (B, r), replica_ok (B, r))``.
    """
    dists, pids = navigate(state, vecs, r)
    dmin = dists[:, :1]
    factor = jnp.float32(state.cfg.replica_rng) ** 2
    replica_ok = (dists <= factor * dmin) & (dists < MASK_DISTANCE / 2)
    return pids, dists, replica_ok


# ---------------------------------------------------------------------------
# External interface: Insert / Delete (the foreground Updater, §4.1)
# ---------------------------------------------------------------------------

@jax.jit
def insert_batch(
    state: IndexState, vecs: Array, vids: Array, valid: Array
) -> tuple[IndexState, Array]:
    """Foreground insert: route to nearest posting(s), append at tail.

    O(1) per append (tail-block write) — splits are *not* done here; the
    background rebuilder discovers oversized postings by length scan.

    Returns ``(state, landed (B,))`` — ``landed`` is False when even the
    *primary* (nearest-posting) append failed because the posting is at hard
    capacity; the host Updater applies backpressure: run maintenance (which
    splits the oversized posting) and retry.  This is the feed-forward
    pipeline of paper §4.2 with explicit backpressure instead of threads.
    """
    cfg = state.cfg

    # (Re)activate the id: clear deletion bit, keep version counter.
    # Disabled rows scatter to the scratch slot (duplicate-index hazard).
    idx = vm._targets(state.versions, vids, valid)
    cur = state.versions[idx]
    cleared = cur & vm.VERSION_MASK
    versions = state.versions.at[idx].set(cleared)
    state = state.replace(versions=versions)

    pids, _, replica_ok = route(state, vecs, cfg.replica_count)
    enable = valid[:, None] & replica_ok  # (B, R)

    flat_pids = pids.reshape(-1)
    flat_enable = enable.reshape(-1)
    flat_vecs = jnp.repeat(vecs, cfg.replica_count, axis=0)
    flat_vids = jnp.repeat(vids, cfg.replica_count)
    flat_vers = jnp.repeat(cleared, cfg.replica_count)

    pool, oks = bp.append_batch(
        state.pool,
        jnp.maximum(flat_pids, 0),
        flat_vecs,
        flat_vids,
        flat_vers,
        flat_enable & (flat_pids >= 0),
    )
    oks2 = oks.reshape(-1, cfg.replica_count)
    landed = oks2[:, 0] | ~valid  # primary append succeeded (or not requested)
    stats = state.stats
    stats = bump_stat(stats, "n_inserts", jnp.sum(valid))
    stats = bump_stat(stats, "n_appends", jnp.sum(oks))
    stats = bump_stat(
        stats, "n_append_drops", jnp.sum(flat_enable & (flat_pids >= 0)) - jnp.sum(oks)
    )
    return state.replace(pool=pool, stats=stats, step=state.step + 1), landed


@jax.jit
def delete_batch(state: IndexState, vids: Array, valid: Array) -> IndexState:
    """Tombstone delete (paper: one thread suffices — it's a bit set)."""
    versions = vm.mark_deleted(state.versions, jnp.maximum(vids, 0), valid)
    stats = bump_stat(state.stats, "n_deletes", jnp.sum(valid))
    return state.replace(versions=versions, stats=stats, step=state.step + 1)


# ---------------------------------------------------------------------------
# Search (the SPANN searcher over versioned postings)
# ---------------------------------------------------------------------------

def _dedup_topk_1d_ref(
    dists: Array, vids: Array, live: Array, k: int
) -> tuple[Array, Array]:
    """Reference dedup-top-k (the original reduce, kept as the oracle for
    tests and the before/after benchmark).

    Sort by (vid primary, dist secondary); keep first occurrence of each vid;
    then masked top-k.  ``jnp.lexsort`` is two full O(n log n) sort passes
    over the candidate array — the hottest reduce in search.

    Caveat (fixed by the replacement): a vid whose *minimum-distance*
    occurrence is dead (stale replica closer than the live one) is dropped
    entirely here; callers must pre-mask dead distances to MASK_DISTANCE
    for live-min semantics (the chunked scan path always did).
    """
    order = jnp.lexsort((dists, vids))
    sv = vids[order]
    sl = live[order]
    sd = dists[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), sv[1:] != sv[:-1]]
    )
    keep = first & sl
    top_d, sel = masked_topk(sd, keep, k)
    out_vids = jnp.where(top_d < MASK_DISTANCE / 2, sv[sel], -1)
    return top_d, out_vids


def _dedup_prefilter(cfg, k: int, n: int) -> int:
    """Static candidate cap for the dedup reduce: the k-th distinct vid must
    sit within the first ``k * max_live_replicas`` distance-sorted entries.
    ``2 * replica_count`` covers the re-insert-live-id case (old replicas of
    the same version stay live next to the fresh ones)."""
    return max(k, min(n, max(4 * k, 2 * k * cfg.replica_count)))


def _dedup_topk_1d(
    dists: Array, vids: Array, live: Array, k: int, prefilter: int
) -> tuple[Array, Array]:
    """Top-k smallest with duplicate-vid suppression (replicas!).

    Replaces the lexsort reduce (see ``_dedup_topk_1d_ref``): one
    ``top_k`` prefilter to ``prefilter`` candidates (distance-sorted, ties
    by index — so within the prefix, an entry's duplicates-with-smaller-
    distance all precede it), then an O(prefilter²) segment-min mask picks
    each vid's first occurrence, then the final masked top-k runs on the
    tiny prefix.  A packed ``(vid << shift | rank)`` single-key sort needs
    64-bit keys (vid caps exceed 2^21), which x64-disabled jax doesn't
    have — the top_k prefilter is strictly cheaper anyway: one partial
    selection instead of two full sorts over n.

    Exact vs the reference whenever each vid has ≤ prefilter/k live
    replicas (callers size ``prefilter`` via ``_dedup_prefilter``); only
    exact cross-vid distance ties can reorder equal-distance results.
    """
    n = dists.shape[0]
    m = min(max(prefilter, k), n)
    d = jnp.where(live, dists, MASK_DISTANCE)
    neg, sel = jax.lax.top_k(-d, m)
    sd = -neg
    sv = vids[sel]
    idx = jnp.arange(m)
    earlier_dup = (sv[:, None] == sv[None, :]) & (idx[:, None] > idx[None, :])
    keep = ~jnp.any(earlier_dup, axis=1) & (sd < MASK_DISTANCE / 2)
    top_d, s2 = masked_topk(sd, keep, k)
    out_vids = jnp.where(top_d < MASK_DISTANCE / 2, sv[s2], -1)
    return top_d, out_vids


def _page_table(
    state: IndexState, pids: Array, probe_valid: Array
) -> Array:
    """Probed pids → block-table rows: ``(Q, nprobe*MB)`` block ids with
    -1 for absent pages and invalid probes."""
    pool = state.pool
    q = pids.shape[0]
    table = pool.posting_blocks[jnp.maximum(pids, 0)]   # (Q, nprobe, MB)
    table = jnp.where(((pids >= 0) & probe_valid)[..., None], table, -1)
    return table.reshape(q, -1)


def _page_slot_live(state: IndexState, pages: Array) -> tuple[Array, Array]:
    """Per-slot (vids, live) metadata for a set of pages ``(..., )`` →
    ``(..., BS)``.  The metadata gather is tiny (5 B/slot vs the d·dtype
    payload the Pallas kernel streams page-by-page)."""
    pool = state.pool
    safe = jnp.maximum(pages, 0)
    pvids = pool.block_vid[safe]
    pvers = pool.block_ver[safe]
    live = (
        (pages >= 0)[..., None]
        & (pvids >= 0)
        & ~vm.is_stale(state.versions, pvids, pvers)
    )
    return pvids, live


def _pallas_scan_candidates(
    state: IndexState, queries: Array, pids: Array, probe_valid: Array,
    *, k: int, schedule: str,
) -> tuple[Array, Array, Array]:
    """Paged Pallas posting scan → reduced candidate set.

    Streams SSD-block-sized pages through the ``posting_scan`` kernels and
    keeps only the per-page ``min(k, BS)`` nearest live candidates, so
    neither the (Q, nprobe·cap, d) gather buffer nor the (Q, nprobe·MB·BS)
    distance matrix ever exists in HBM.  Returns ``(dists (Q, n),
    vids (Q, n), live (Q, n))`` with n = pages·kpage.

    ``schedule="per_query"`` streams every probed page once per query
    (paper-faithful ParallelGET).  ``schedule="batched"`` dedups the whole
    micro-batch's pages to a static ``scan_page_budget`` (overflow drops
    the highest-numbered pages — see ``ops.dedup_pages``) and scores each
    unique page against all queries with one MXU GEMM; candidates are then
    masked back to each query's own probe set, so results match the
    per-query schedule whenever the budget holds every unique page.
    """
    cfg = state.cfg
    pool = state.pool
    q, nprobe = pids.shape
    mb = pool.max_blocks_per_posting
    kpage = min(k, pool.block_size)
    interp = cfg.pallas_interpret
    flat = _page_table(state, pids, probe_valid)        # (Q, NB)

    if schedule == "per_query":
        pvids, live = _page_slot_live(state, flat)      # (Q, NB, BS)
        d, slots = scan_ops.scan_posting_blocks_topk(
            queries, flat, live, pool.blocks, k=kpage, interpret=interp
        )                                               # (Q, NB, kpage)
        cand_v = jnp.take_along_axis(pvids, slots, axis=2)
        cand_d = d.reshape(q, -1)
        cand_v = cand_v.reshape(q, -1)
    elif schedule == "batched":
        budget = cfg.scan_page_budget or min(q * nprobe * mb, cfg.num_blocks)
        uniq, member_pos, _, _ = scan_ops.dedup_pages(
            flat.reshape(-1), budget=budget, num_blocks=cfg.num_blocks
        )
        pvids, live = _page_slot_live(state, uniq)      # (budget, BS)
        d, slots = scan_ops.scan_unique_blocks_topk(
            queries, uniq, live, pool.blocks, k=kpage, interpret=interp
        )                                               # (budget, Q, kpage)
        page_v = jnp.take_along_axis(pvids[:, None, :], slots, axis=2)
        # gather each query's own probed pages back out of the unique-page
        # tiles (parity with the per-query schedule: a page another query
        # probed must not leak in) — the reduce then sees the per-query
        # (Q, NB, kpage) candidate shape, NOT (Q, budget, kpage)
        mp = member_pos.reshape(q, -1)                  # (Q, NB)
        safe_mp = jnp.maximum(mp, 0)
        qi = jnp.arange(q)[:, None]
        cand_d = jnp.where(
            (mp >= 0)[:, :, None], d[safe_mp, qi], MASK_DISTANCE
        ).reshape(q, -1)
        cand_v = page_v[safe_mp, qi].reshape(q, -1)
    else:
        raise ValueError(
            f"scan_schedule must be 'per_query' or 'batched', got {schedule!r}"
        )
    return cand_d, cand_v, cand_d < MASK_DISTANCE / 2


@functools.partial(jax.jit, static_argnames=("nprobe", "scan_page_budget"))
def scan_page_stats(
    state: IndexState,
    queries: Array,
    *,
    nprobe: int | None = None,
    scan_page_budget: int | None = None,
) -> dict[str, Array]:
    """Batched-schedule page accounting for a query micro-batch.

    The search hot path cannot surface the dedup counters (it returns only
    ``(dists, vids)``), so overflow accounting lives here: run it on a
    representative micro-batch to size ``scan_page_budget`` and to watch
    for silent recall loss (``overflow > 0`` means the budget dropped
    probed pages).  ``benchmarks/run.py --json`` reports it per workload.

    Returns ``{"n_pages", "n_unique", "overflow"}`` (device scalars).
    """
    cfg = state.cfg
    nprobe = cfg.nprobe if nprobe is None else nprobe
    budget = scan_page_budget if scan_page_budget is not None \
        else cfg.scan_page_budget
    budget = budget or min(
        queries.shape[0] * nprobe * cfg.max_blocks_per_posting,
        cfg.num_blocks,
    )
    nav_d, pids = navigate(state, queries, nprobe)
    probe_valid = nav_d < MASK_DISTANCE / 2
    flat = _page_table(state, pids, probe_valid)
    _, _, n_unique, overflow = scan_ops.dedup_pages(
        flat.reshape(-1), budget=budget, num_blocks=cfg.num_blocks
    )
    return {
        "n_pages": jnp.sum(flat >= 0),
        "n_unique": n_unique,
        "overflow": overflow,
    }


def _scan_probe_chunk(
    state: IndexState, queries: Array, pids: Array, probe_valid: Array
) -> tuple[Array, Array, Array]:
    """Score one chunk of probed postings.  queries (Q, d); pids (Q, c).
    Returns (dists (Q, c*cap), vids, live)."""
    cfg = state.cfg
    q, c = pids.shape
    cap = cfg.posting_capacity
    flat_pids = jnp.maximum(pids.reshape(-1), 0)
    vecs, vids, vers, slot_valid = bp.parallel_get(state.pool, flat_pids)
    stale = vm.is_stale(state.versions, vids, vers)
    live = slot_valid & ~stale & probe_valid.reshape(-1)[:, None]
    vecs = vecs.reshape(q, c * cap, -1)
    vids = vids.reshape(q, c * cap)
    live = live.reshape(q, c * cap)
    # scan math in cfg.scan_dtype (bf16 on TPU) with f32 accumulation —
    # halves the upcast traffic of int8 payloads (§Perf spfresh iter 2)
    sd = jnp.dtype(cfg.scan_dtype)
    qv = queries.astype(sd)
    xv = vecs.astype(sd)
    diff = qv[:, None, :] - xv
    dists = jnp.sum(
        (diff * diff).astype(jnp.float32), axis=-1
    )
    return dists, vids, live


def scan_and_reduce(
    state: IndexState,
    queries: Array,
    pids: Array,
    probe_valid: Array,
    *,
    k: int,
    probe_chunk: int = 0,
    use_pallas_scan: bool | None = None,
    scan_schedule: str | None = None,
) -> tuple[Array, Array]:
    """Posting scan + dedup top-k over an already-navigated probe set.

    Shared by ``search`` and the grouped two-level search; the scan data
    path is selected here:

    * **Pallas paged scan** (``use_pallas_scan``, schedule per
      ``scan_schedule`` — both default to the config flags): pages stream
      HBM→VMEM through the ``posting_scan`` kernels, which emit per-page
      k-min candidates; the reduce then works on (Q, pages·kpage)
      candidates.  ``probe_chunk`` is ignored — the kernel grid already
      streams page-at-a-time, and the candidate buffer is k-reduced.
    * **XLA gather oracle** (default): ``bp.parallel_get`` materializes
      the (Q, nprobe·cap, d) probe buffer; ``probe_chunk > 0`` processes
      the probes in chunks with a running candidate set so the buffer is
      O(Q · chunk · cap · d).
    """
    cfg = state.cfg
    q, nprobe = pids.shape
    cap = cfg.posting_capacity
    pallas = cfg.use_pallas_scan if use_pallas_scan is None else use_pallas_scan
    schedule = scan_schedule if scan_schedule is not None else cfg.scan_schedule

    if pallas:
        cand_d, cand_v, live = _pallas_scan_candidates(
            state, queries, pids, probe_valid, k=k, schedule=schedule
        )
        m = _dedup_prefilter(cfg, k, cand_d.shape[1])
        return jax.vmap(lambda d, v, mm: _dedup_topk_1d(d, v, mm, k, m))(
            cand_d, cand_v, live
        )

    if probe_chunk <= 0 or nprobe % probe_chunk != 0 or nprobe == probe_chunk:
        dists, vids, live = _scan_probe_chunk(state, queries, pids, probe_valid)
        m = _dedup_prefilter(cfg, k, dists.shape[1])
        return jax.vmap(lambda d, v, mm: _dedup_topk_1d(d, v, mm, k, m))(
            dists, vids, live
        )

    nc = nprobe // probe_chunk
    keep = min(max(4 * k, 64), probe_chunk * cap)
    pids_c = pids.reshape(q, nc, probe_chunk).transpose(1, 0, 2)
    pvalid_c = probe_valid.reshape(q, nc, probe_chunk).transpose(1, 0, 2)

    def body(carry, inp):
        best_d, best_v = carry  # (Q, keep)
        pc, vc = inp
        d, v, live = _scan_probe_chunk(state, queries, pc, vc)
        d = jnp.where(live, d, MASK_DISTANCE)
        cat_d = jnp.concatenate([best_d, d], axis=1)
        cat_v = jnp.concatenate([best_v, v], axis=1)
        neg, sel = jax.lax.top_k(-cat_d, keep)
        return (-neg, jnp.take_along_axis(cat_v, sel, axis=1)), None

    init = (
        jnp.full((q, keep), MASK_DISTANCE, jnp.float32),
        jnp.full((q, keep), -1, jnp.int32),
    )
    (best_d, best_v), _ = jax.lax.scan(body, init, (pids_c, pvalid_c))
    live = best_d < MASK_DISTANCE / 2
    m = _dedup_prefilter(cfg, k, keep)
    return jax.vmap(lambda d, v, mm: _dedup_topk_1d(d, v, mm, k, m))(
        best_d, best_v, live
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "nprobe", "probe_chunk", "use_pallas_scan", "scan_schedule"
    ),
)
def search(
    state: IndexState,
    queries: Array,
    *,
    k: int,
    nprobe: int | None = None,
    probe_chunk: int = 0,
    use_pallas_scan: bool | None = None,
    scan_schedule: str | None = None,
) -> tuple[Array, Array]:
    """ANN search: centroid navigation → posting scan → dedup top-k.

    Returns ``(dists (Q, k), vids (Q, k))``; missing results are ``-1`` with
    MASK_DISTANCE.  ``nprobe`` is the latency-budget knob (the paper's 10 ms
    hard cut becomes a fixed candidate budget under jit).

    The posting-scan data path (Pallas paged streaming vs XLA gather, and
    the per-query vs batch-dedup page schedule) is selected by
    ``use_pallas_scan`` / ``scan_schedule`` — ``None`` defers to the
    config flags.  See ``scan_and_reduce`` for the probe_chunk semantics
    of the oracle path.
    """
    cfg = state.cfg
    nprobe = cfg.nprobe if nprobe is None else nprobe
    nav_d, pids = navigate(state, queries, nprobe)  # (Q, nprobe)
    probe_valid = nav_d < MASK_DISTANCE / 2
    return scan_and_reduce(
        state, queries, pids, probe_valid,
        k=k, probe_chunk=probe_chunk,
        use_pallas_scan=use_pallas_scan, scan_schedule=scan_schedule,
    )


# ---------------------------------------------------------------------------
# Reassignment execution (shared by split and merge)
# ---------------------------------------------------------------------------

def _execute_reassigns(
    state: IndexState,
    cand_vecs: Array,   # (C, d)
    cand_vids: Array,   # (C,)
    cand_cur_pid: Array,  # (C,) posting the candidate currently lives in
    cand_mask: Array,   # (C,) passed the necessary conditions
) -> IndexState:
    """Paper §3.3 final stage: per candidate, search the new closest posting,
    NPA-recheck to drop false positives, then version-bump + re-append.

    Candidates are compacted to ``reassign_budget`` rows (overflow counted —
    the paper reports ~79 actual reassigns out of ~5094 evaluated, so the
    budget is generous).
    """
    cfg = state.cfg
    c = cand_vecs.shape[0]
    budget = min(cfg.reassign_budget, c)

    # --- compact to budget ---
    order = jnp.argsort(~cand_mask, stable=True)  # True (mask) rows first
    take = order[:budget]
    vecs = cand_vecs[take]
    vids = cand_vids[take]
    cur_pid = cand_cur_pid[take]
    mask = cand_mask[take]
    n_cand = jnp.sum(cand_mask)
    overflow = jnp.maximum(n_cand - budget, 0)

    # --- dedup same vid within the batch (concurrent-reassign CAS analogue) ---
    same = (vids[:, None] == vids[None, :]) & (
        jnp.arange(budget)[:, None] > jnp.arange(budget)[None, :]
    )
    dup = jnp.any(same & mask[None, :], axis=1)
    mask = mask & ~dup
    # Deleted/stale ids never get reassigned (they get GC'd instead).
    mask = mask & ~vm.is_deleted(state.versions, jnp.maximum(vids, 0)) & (vids >= 0)

    # --- NPA re-check: find the true nearest posting now ---
    pids, dists, replica_ok = route(state, vecs, cfg.replica_count)
    nearest = pids[:, 0]
    # False-positive filter (paper: "if a vector actually does not need
    # reassignment, the reassign operation is aborted"): if a LIVE replica of
    # this vid already sits in the nearest posting, NPA is satisfied.
    safe_vids = jnp.maximum(vids, 0)
    cur_ver = state.versions[safe_vids] & vm.VERSION_MASK
    t_vids, t_vers, t_valid = jax.vmap(
        lambda p: bp.gather_posting_ids(state.pool, p)
    )(jnp.maximum(nearest, 0))  # (budget, cap)
    replica_there = jnp.any(
        (t_vids == vids[:, None])
        & t_valid
        & ((t_vers & vm.VERSION_MASK) == cur_ver[:, None]),
        axis=-1,
    )
    need = mask & (nearest >= 0) & (nearest != cur_pid) & ~replica_there

    # --- append fresh replicas at the new homes with a TENTATIVE version ---
    # The version map is only bumped if the primary append lands; otherwise
    # the old replicas stay live (no data loss when the target is full) and
    # the tentative appends are stale garbage, GC'd by the next split.
    tentative_ver = (cur_ver + 1) & vm.VERSION_MASK
    enable = need[:, None] & replica_ok & (pids >= 0)
    flat_pids = jnp.maximum(pids.reshape(-1), 0)
    flat_enable = enable.reshape(-1)
    flat_vecs = jnp.repeat(vecs, cfg.replica_count, axis=0)
    flat_vids = jnp.repeat(vids, cfg.replica_count)
    flat_vers = jnp.repeat(tentative_ver, cfg.replica_count)
    pool, oks = bp.append_batch(
        state.pool, flat_pids, flat_vecs, flat_vids, flat_vers, flat_enable
    )
    landed = oks.reshape(-1, cfg.replica_count)[:, 0]
    commit = need & landed
    versions = vm.bump_version(state.versions, safe_vids, commit)
    state = state.replace(versions=versions)

    stats = state.stats
    stats = bump_stat(stats, "n_reassign_candidates", n_cand)
    stats = bump_stat(stats, "n_reassign_overflow", overflow)
    stats = bump_stat(stats, "n_reassigned", jnp.sum(commit))
    stats = bump_stat(stats, "n_appends", jnp.sum(oks))
    stats = bump_stat(
        stats, "n_append_drops", jnp.sum(flat_enable) - jnp.sum(oks)
    )
    return state.replace(pool=pool, stats=stats)


# ---------------------------------------------------------------------------
# Split (Local Rebuilder job, §4.2.1)
# ---------------------------------------------------------------------------

@jax.jit
def split_posting(
    state: IndexState, pid: Array, enable: Array
) -> tuple[IndexState, Array]:
    """Split job: GC the posting; if still oversized, balanced-2-means split,
    then LIRE reassignment over the split + ``reassign_range`` neighbors.

    Returns ``(state, acted)`` where acted covers both GC-writeback and true
    splits.
    """
    cfg = state.cfg
    cap = cfg.posting_capacity
    pid = jnp.asarray(pid, jnp.int32)
    enable = enable & (pid >= 0) & state.centroid_valid[jnp.maximum(pid, 0)]
    safe_pid = jnp.maximum(pid, 0)

    vecs, vids, vers, valid = bp.gather_posting(state.pool, safe_pid)
    live = valid & ~vm.is_stale(state.versions, vids, vers)
    n_live = jnp.sum(live)
    cur_len = state.pool.posting_len[safe_pid]
    cur_ver = state.versions[jnp.maximum(vids, 0)] & vm.VERSION_MASK

    # ---- Case A: garbage-collection write-back resolves the job ----
    gc_wb = enable & (n_live <= cfg.split_limit) & (n_live < cur_len)
    order_live = jnp.argsort(~live, stable=True)
    pool, _ = bp.put_posting(
        state.pool,
        safe_pid,
        vecs[order_live],
        vids[order_live],
        cur_ver[order_live],
        n_live,
        gc_wb,
    )
    state = state.replace(pool=pool)

    # ---- Case B: real split ----
    want_split = enable & (n_live > cfg.split_limit)
    if not cfg.enable_split:
        want_split = jnp.asarray(False)
    rng, sub = jax.random.split(state.rng)
    state = state.replace(rng=rng)
    new_centroids, assign = balanced_two_means(
        sub, vecs.astype(jnp.float32), live, iters=cfg.kmeans_iters
    )

    state, pid1 = alloc_pid(state, want_split)
    state, pid2 = alloc_pid(state, want_split)
    ok = want_split & (pid1 >= 0) & (pid2 >= 0)
    # Roll back a half-successful allocation.
    state = free_pid(state, pid1, want_split & ~ok)
    state = free_pid(state, pid2, want_split & ~ok)

    old_centroid = state.centroids[safe_pid]

    # Retire the old posting (blocks + centroid + id).
    pool = bp.free_posting(state.pool, safe_pid, ok)
    state = state.replace(pool=pool)
    state = free_pid(state, pid, ok)

    # Write the two halves.
    in0 = live & (assign == 0)
    in1 = live & (assign == 1)
    n0 = jnp.sum(in0)
    n1 = jnp.sum(in1)
    order0 = jnp.argsort(~in0, stable=True)
    order1 = jnp.argsort(~in1, stable=True)
    pool, ok_put0 = bp.put_posting(
        state.pool, jnp.maximum(pid1, 0), vecs[order0], vids[order0],
        cur_ver[order0], n0, ok,
    )
    pool, ok_put1 = bp.put_posting(
        pool, jnp.maximum(pid2, 0), vecs[order1], vids[order1],
        cur_ver[order1], n1, ok,
    )
    state = state.replace(pool=pool)
    state = set_centroid(state, pid1, new_centroids[0], ok)
    state = set_centroid(state, pid2, new_centroids[1], ok)

    # ---- Reassignment (the heart of LIRE) ----
    # Neighbors: reassign_range nearest postings to the *old* centroid,
    # excluding the two freshly created ones.
    nb_d = pairwise_sql2(
        old_centroid[None, :], state.centroids, state.centroid_sqn
    )[0]
    nb_valid_mask = state.centroid_valid & (
        jnp.arange(cfg.num_postings_cap) != jnp.maximum(pid1, 0)
    ) & (jnp.arange(cfg.num_postings_cap) != jnp.maximum(pid2, 0))
    nb_dist, nb_pids = masked_topk(
        nb_d[None, :], nb_valid_mask[None, :], cfg.reassign_range
    )
    nb_pids = nb_pids[0]
    nb_ok = (nb_dist[0] < MASK_DISTANCE / 2)

    nvecs, nvids, nvers, nvalid = bp.parallel_get(
        state.pool, jnp.maximum(nb_pids, 0)
    )  # (RR, cap, ...)
    nlive = nvalid & ~vm.is_stale(state.versions, nvids, nvers)
    nlive = nlive & nb_ok[:, None]

    flat_nvecs = nvecs.reshape(-1, cfg.dim)
    flat_nvids = nvids.reshape(-1)
    flat_nlive = nlive.reshape(-1)
    flat_ncur = jnp.repeat(nb_pids, cap)

    # Eq. (2) for neighbor vectors; Eq. (1) for the split posting's vectors.
    eq2 = npa.split_neighbor_candidates(
        flat_nvecs.astype(jnp.float32), old_centroid, new_centroids
    )
    eq1 = npa.split_old_posting_candidates(
        vecs.astype(jnp.float32), old_centroid, new_centroids
    )
    own_cur = jnp.where(assign == 0, jnp.maximum(pid1, 0), jnp.maximum(pid2, 0))

    cand_vecs = jnp.concatenate([vecs, flat_nvecs], axis=0)
    cand_vids = jnp.concatenate([vids, flat_nvids], axis=0)
    cand_cur = jnp.concatenate([own_cur, flat_ncur], axis=0)
    cand_mask = jnp.concatenate(
        [eq1 & live & ok, eq2 & flat_nlive & ok], axis=0
    )

    checked = jnp.where(ok, jnp.sum(live) + jnp.sum(flat_nlive), 0)
    stats = bump_stat(state.stats, "n_reassign_checked", checked)
    stats = bump_stat(stats, "n_splits", ok)
    stats = bump_stat(stats, "n_gc_writebacks", gc_wb)
    state = state.replace(stats=stats, step=state.step + 1)

    if cfg.enable_reassign:
        state = _execute_reassigns(
            state, cand_vecs, cand_vids, cand_cur, cand_mask
        )
    return state, (ok | gc_wb)


# ---------------------------------------------------------------------------
# Merge (Local Rebuilder job, §3.2 / §4.2.1)
# ---------------------------------------------------------------------------

@jax.jit
def merge_posting(
    state: IndexState, pid: Array, enable: Array
) -> tuple[IndexState, Array]:
    """Merge job: append the undersized posting's live vectors into the
    nearest posting that can hold them, delete its centroid, then run the
    (neighbor-free) reassignment check over the moved vectors.
    """
    cfg = state.cfg
    pid = jnp.asarray(pid, jnp.int32)
    safe_pid = jnp.maximum(pid, 0)
    enable = enable & (pid >= 0) & state.centroid_valid[safe_pid]

    vecs, vids, vers, valid = bp.gather_posting(state.pool, safe_pid)
    live = valid & ~vm.is_stale(state.versions, vids, vers)
    n_live = jnp.sum(live)
    enable = enable & (n_live < cfg.merge_limit)

    # Nearest posting able to absorb us: try the 4 closest.
    own_centroid = state.centroids[safe_pid]
    d = pairwise_sql2(own_centroid[None, :], state.centroids, state.centroid_sqn)[0]
    cand_mask = state.centroid_valid & (
        jnp.arange(cfg.num_postings_cap) != safe_pid
    )
    cd, cpids = masked_topk(d[None, :], cand_mask[None, :], 4)
    cd, cpids = cd[0], cpids[0]
    fits = (cd < MASK_DISTANCE / 2) & (
        state.pool.posting_len[jnp.maximum(cpids, 0)] + n_live
        <= cfg.posting_capacity
    )
    any_fit = jnp.any(fits)
    first_fit = jnp.argmax(fits)  # first True
    target = jnp.where(any_fit, cpids[first_fit], -1)
    do = enable & any_fit & (n_live > 0)
    # Empty postings are simply retired.
    retire_empty = enable & (n_live == 0)

    cur_ver = state.versions[jnp.maximum(vids, 0)] & vm.VERSION_MASK
    pool, oks = bp.append_batch(
        state.pool,
        jnp.full_like(vids, jnp.maximum(target, 0)),
        vecs,
        vids,
        cur_ver,
        live & do,
    )
    state = state.replace(pool=pool)

    # Retire the merged-away posting — only if every live vector actually
    # landed in the target (pool OOM mid-merge must not lose vectors).
    all_moved = jnp.all(oks == (live & do))
    do = do & all_moved
    gone = do | retire_empty
    pool = bp.free_posting(state.pool, safe_pid, gone)
    state = state.replace(pool=pool)
    state = free_pid(state, pid, gone)

    # Reassign check over moved vectors only (no neighbor scan for merges).
    state = state.replace(
        stats=bump_stat(
            bump_stat(state.stats, "n_merges", do),
            "n_reassign_checked", jnp.where(do, n_live, 0),
        ),
        step=state.step + 1,
    )
    cand_cur = jnp.full_like(vids, jnp.maximum(target, 0))
    if cfg.enable_reassign:
        state = _execute_reassigns(state, vecs, vids, cand_cur, live & do)
    return state, gone


# ---------------------------------------------------------------------------
# Maintenance driver (the Local Rebuilder queue, discovered by length scan)
# ---------------------------------------------------------------------------

@jax.jit
def maintenance_step(state: IndexState) -> tuple[IndexState, Array]:
    """One background rebuild step: split the most oversized posting (if
    any), merge the most undersized (if any).  Constant work; returns
    ``(state, did_work)``.

    The §3.4 convergence argument bounds how many steps a driver loop needs:
    each split consumes a free posting id, so ``P_cap`` is a hard bound on
    cascade length.
    """
    cfg = state.cfg
    lens = state.pool.posting_len
    valid = state.centroid_valid

    split_scores = jnp.where(valid, lens, -1)
    split_pid = jnp.argmax(split_scores).astype(jnp.int32)
    want_split = split_scores[split_pid] > cfg.split_limit
    state, split_acted = split_posting(state, split_pid, want_split)

    merge_scores = jnp.where(
        valid & (lens < cfg.merge_limit), lens, jnp.iinfo(jnp.int32).max
    )
    merge_pid = jnp.argmin(merge_scores).astype(jnp.int32)
    want_merge = merge_scores[merge_pid] < cfg.merge_limit
    if not cfg.enable_merge:
        want_merge = jnp.asarray(False)
    state, merge_acted = merge_posting(state, merge_pid, want_merge)

    return state, (split_acted | merge_acted)


def rebuild_drain(
    state: IndexState, max_steps: int | None = None
) -> tuple[IndexState, int]:
    """Host-driven Local Rebuilder loop: run maintenance steps until
    quiescent.  Bounded by the convergence proof (≤ P_cap splits possible).
    """
    limit = max_steps if max_steps is not None else 2 * state.cfg.num_postings_cap
    steps = 0
    for _ in range(limit):
        state, did = maintenance_step(state)
        steps += 1
        if not bool(did):
            break
    return state, steps
