"""LIRE protocol operations — paper §3 + §4.2.

External interface: :func:`insert_batch`, :func:`delete_batch`,
:func:`search`.  Internal (Local Rebuilder): :func:`split_posting`,
:func:`merge_posting`, :func:`maintenance_step`, and the batched
:func:`maintenance_round` (K split + K merge jobs with one fused
reassignment pass — the update-path analogue of the batched search scan).

Every op is a jittable, fixed-shape functional state transition.  Branchy
protocol logic is expressed with ``enable`` masks threaded through the
storage ops, so a maintenance step is constant work regardless of whether a
job fires (the TPU idiom for the paper's background job queue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import npa
from repro.core.clustering import balanced_two_means
from repro.core.distance import MASK_DISTANCE, masked_topk, pairwise_sql2, sql2
from repro.core.types import (
    IndexState,
    LireStats,
    alloc_pids,
    bump_stat,
    free_pids,
    set_centroids,
)
from repro.kernels.posting_scan import ops as scan_ops
from repro.storage import blockpool as bp
from repro.storage import versionmap as vm

Array = jax.Array


# ---------------------------------------------------------------------------
# Centroid navigation (the SPTAG replacement: dense GEMM + top-k)
# ---------------------------------------------------------------------------

def navigate(state: IndexState, queries: Array, nprobe: int) -> tuple[Array, Array]:
    """Nearest-``nprobe`` valid posting centroids for each query.

    Returns ``(dists (Q, nprobe), pids (Q, nprobe))``; invalid slots have
    MASK_DISTANCE.  With ``cfg.use_pallas_nav`` the fused Pallas ``l2_topk``
    kernel runs (TPU target; interpret mode on CPU); the pure-XLA GEMM +
    masked top-k below is the oracle and the default CPU path.
    """
    if state.cfg.use_pallas_nav:
        from repro.kernels.l2_topk.ops import l2_topk

        d, idx = l2_topk(
            queries, state.centroids, state.centroid_valid, k=nprobe,
            interpret=state.cfg.pallas_interpret,
        )
        d = jnp.where(idx >= 0, d, MASK_DISTANCE)
        return d, idx
    d = pairwise_sql2(queries, state.centroids, state.centroid_sqn)
    return masked_topk(d, state.centroid_valid[None, :], nprobe)


def route(
    state: IndexState, vecs: Array, r: int
) -> tuple[Array, Array, Array]:
    """Insert/reassign routing: top-``r`` centroids + closure-replica mask.

    A vector is replicated into posting ``i`` iff
    ``d_i <= replica_rng^2 * d_min`` (SPANN closure rule, squared-L2 form).
    Returns ``(pids (B, r), dists (B, r), replica_ok (B, r))``.
    """
    dists, pids = navigate(state, vecs, r)
    dmin = dists[:, :1]
    factor = jnp.float32(state.cfg.replica_rng) ** 2
    replica_ok = (dists <= factor * dmin) & (dists < MASK_DISTANCE / 2)
    return pids, dists, replica_ok


# ---------------------------------------------------------------------------
# Per-posting telemetry (Ada-IVF cost-model inputs, bumped in jitted steps)
# ---------------------------------------------------------------------------

def _bump_append_telemetry(
    state: IndexState, pids: Array, vecs: Array, landed: Array
):
    """Update/drift accounting for a batch of physical appends (insert
    replicas, reassign re-appends, merge moves): every landed row bumps its
    posting's ``update_count`` and accumulates its displacement from the
    CURRENT centroid into ``drift_vec``.  Runs inside the jitted update
    steps, so WAL replay reproduces the leaves bit-exactly."""
    tel = state.telemetry
    cap = state.cfg.num_postings_cap
    safe = jnp.maximum(pids, 0)
    tgt = jnp.where(landed, safe, cap)
    disp = vecs.astype(jnp.float32) - state.centroids[safe]
    disp = jnp.where(landed[:, None], disp, 0.0)
    return tel.replace(
        update_count=tel.update_count.at[tgt].add(1, mode="drop"),
        drift_vec=tel.drift_vec.at[tgt].add(disp, mode="drop"),
    )


def probe_histogram(cfg, pids: Array, probe_valid: Array) -> Array:
    """Per-posting probe counts for one search micro-batch — the access
    signal of the drift-aware maintenance policy.  Searches are NOT
    WAL-logged, so this histogram never touches ``IndexState`` here: the
    serving backend accumulates it host-side and folds it in as an operand
    of the next WAL-logged maintenance dispatch (replay stays bit-exact)."""
    cap = cfg.num_postings_cap
    tgt = jnp.where(probe_valid, pids, cap).reshape(-1)
    return jnp.zeros((cap,), jnp.int32).at[tgt].add(1, mode="drop")


# ---------------------------------------------------------------------------
# External interface: Insert / Delete (the foreground Updater, §4.1)
# ---------------------------------------------------------------------------

@jax.jit
def insert_batch(
    state: IndexState, vecs: Array, vids: Array, valid: Array
) -> tuple[IndexState, Array]:
    """Foreground insert: route to nearest posting(s), append at tail.

    O(1) per append (tail-block write) — splits are *not* done here; the
    background rebuilder discovers oversized postings by length scan.

    Returns ``(state, landed (B,))`` — ``landed`` is False when even the
    *primary* (nearest-posting) append failed because the posting is at hard
    capacity; the host Updater applies backpressure: run maintenance (which
    splits the oversized posting) and retry.  This is the feed-forward
    pipeline of paper §4.2 with explicit backpressure instead of threads.
    """
    cfg = state.cfg

    # (Re)activate the id: clear deletion bit, keep version counter.
    # Disabled rows scatter to the scratch slot (duplicate-index hazard).
    idx = vm._targets(state.versions, vids, valid)
    cur = state.versions[idx]
    cleared = cur & vm.VERSION_MASK
    versions = state.versions.at[idx].set(cleared)
    state = state.replace(versions=versions)

    pids, _, replica_ok = route(state, vecs, cfg.replica_count)
    enable = valid[:, None] & replica_ok  # (B, R)

    flat_pids = pids.reshape(-1)
    flat_enable = enable.reshape(-1)
    flat_vecs = jnp.repeat(vecs, cfg.replica_count, axis=0)
    flat_vids = jnp.repeat(vids, cfg.replica_count)
    flat_vers = jnp.repeat(cleared, cfg.replica_count)

    pool, oks = bp.append_batch(
        state.pool,
        jnp.maximum(flat_pids, 0),
        flat_vecs,
        flat_vids,
        flat_vers,
        flat_enable & (flat_pids >= 0),
    )
    oks2 = oks.reshape(-1, cfg.replica_count)
    landed = oks2[:, 0] | ~valid  # primary append succeeded (or not requested)
    telemetry = _bump_append_telemetry(state, flat_pids, flat_vecs, oks)
    stats = state.stats
    stats = bump_stat(stats, "n_inserts", jnp.sum(valid))
    stats = bump_stat(stats, "n_appends", jnp.sum(oks))
    stats = bump_stat(
        stats, "n_append_drops", jnp.sum(flat_enable & (flat_pids >= 0)) - jnp.sum(oks)
    )
    return state.replace(
        pool=pool, stats=stats, telemetry=telemetry, step=state.step + 1
    ), landed


@jax.jit
def delete_batch(state: IndexState, vids: Array, valid: Array) -> IndexState:
    """Tombstone delete (paper: one thread suffices — it's a bit set)."""
    versions = vm.mark_deleted(state.versions, jnp.maximum(vids, 0), valid)
    stats = bump_stat(state.stats, "n_deletes", jnp.sum(valid))
    return state.replace(versions=versions, stats=stats, step=state.step + 1)


# ---------------------------------------------------------------------------
# Search (the SPANN searcher over versioned postings)
# ---------------------------------------------------------------------------

def _dedup_topk_1d_ref(
    dists: Array, vids: Array, live: Array, k: int
) -> tuple[Array, Array]:
    """Reference dedup-top-k (the original reduce, kept as the oracle for
    tests and the before/after benchmark).

    Sort by (vid primary, dist secondary); keep first occurrence of each vid;
    then masked top-k.  ``jnp.lexsort`` is two full O(n log n) sort passes
    over the candidate array — the hottest reduce in search.

    Caveat (fixed by the replacement): a vid whose *minimum-distance*
    occurrence is dead (stale replica closer than the live one) is dropped
    entirely here; callers must pre-mask dead distances to MASK_DISTANCE
    for live-min semantics (the chunked scan path always did).
    """
    order = jnp.lexsort((dists, vids))
    sv = vids[order]
    sl = live[order]
    sd = dists[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), sv[1:] != sv[:-1]]
    )
    keep = first & sl
    top_d, sel = masked_topk(sd, keep, k)
    out_vids = jnp.where(top_d < MASK_DISTANCE / 2, sv[sel], -1)
    return top_d, out_vids


def _dedup_prefilter(cfg, k: int, n: int) -> int:
    """Static candidate cap for the dedup reduce: the k-th distinct vid must
    sit within the first ``k * max_live_replicas`` distance-sorted entries.
    ``2 * replica_count`` covers the re-insert-live-id case (old replicas of
    the same version stay live next to the fresh ones)."""
    return max(k, min(n, max(4 * k, 2 * k * cfg.replica_count)))


def _dedup_topk_1d_full(
    dists: Array, vids: Array, live: Array, k: int, prefilter: int
) -> tuple[Array, Array, Array]:
    """Top-k smallest with duplicate-vid suppression (replicas!).

    Replaces the lexsort reduce (see ``_dedup_topk_1d_ref``): one
    ``top_k`` prefilter to ``prefilter`` candidates (distance-sorted, ties
    by index — so within the prefix, an entry's duplicates-with-smaller-
    distance all precede it), then an O(prefilter²) segment-min mask picks
    each vid's first occurrence, then the final masked top-k runs on the
    tiny prefix.  A packed ``(vid << shift | rank)`` single-key sort needs
    64-bit keys (vid caps exceed 2^21), which x64-disabled jax doesn't
    have — the top_k prefilter is strictly cheaper anyway: one partial
    selection instead of two full sorts over n.

    Exact vs the reference whenever each vid has ≤ prefilter/k live
    replicas (callers size ``prefilter`` via ``_dedup_prefilter``); only
    exact cross-vid distance ties can reorder equal-distance results.

    Returns ``(top_d (k,), out_vids (k,), orig_idx (k,))`` — ``orig_idx``
    is each winner's index into the input candidate array (-1 for masked
    rows), which the rerank uses to recover candidate pool positions.
    """
    n = dists.shape[0]
    m = min(max(prefilter, k), n)
    d = jnp.where(live, dists, MASK_DISTANCE)
    neg, sel = jax.lax.top_k(-d, m)
    sd = -neg
    sv = vids[sel]
    idx = jnp.arange(m)
    earlier_dup = (sv[:, None] == sv[None, :]) & (idx[:, None] > idx[None, :])
    keep = ~jnp.any(earlier_dup, axis=1) & (sd < MASK_DISTANCE / 2)
    top_d, s2 = masked_topk(sd, keep, k)
    ok = top_d < MASK_DISTANCE / 2
    out_vids = jnp.where(ok, sv[s2], -1)
    orig_idx = jnp.where(ok, sel[s2], -1)
    return top_d, out_vids, orig_idx


def _dedup_topk_1d(
    dists: Array, vids: Array, live: Array, k: int, prefilter: int
) -> tuple[Array, Array]:
    """`_dedup_topk_1d_full` without the candidate-index output."""
    top_d, out_vids, _ = _dedup_topk_1d_full(dists, vids, live, k, prefilter)
    return top_d, out_vids


def _page_table(
    state: IndexState, pids: Array, probe_valid: Array
) -> Array:
    """Probed pids → block-table rows: ``(Q, nprobe*MB)`` block ids with
    -1 for absent pages and invalid probes."""
    pool = state.pool
    q = pids.shape[0]
    table = pool.posting_blocks[jnp.maximum(pids, 0)]   # (Q, nprobe, MB)
    table = jnp.where(((pids >= 0) & probe_valid)[..., None], table, -1)
    return table.reshape(q, -1)


def _page_slot_live(state: IndexState, pages: Array) -> tuple[Array, Array]:
    """Per-slot (vids, live) metadata for a set of pages ``(..., )`` →
    ``(..., BS)``.  The metadata gather is tiny (5 B/slot vs the d·dtype
    payload the Pallas kernel streams page-by-page)."""
    pool = state.pool
    safe = jnp.maximum(pages, 0)
    pvids = pool.block_vid[safe]
    pvers = pool.block_ver[safe]
    live = (
        (pages >= 0)[..., None]
        & (pvids >= 0)
        & ~vm.is_stale(state.versions, pvids, pvers)
    )
    return pvids, live


def _pallas_scan_candidates(
    state: IndexState, queries: Array, pids: Array, probe_valid: Array,
    *, k: int, schedule: str,
) -> tuple[Array, Array, Array, Array]:
    """Paged Pallas posting scan → reduced candidate set.

    Streams SSD-block-sized pages through the ``posting_scan`` kernels and
    keeps only the per-page ``min(k, BS)`` nearest live candidates, so
    neither the (Q, nprobe·cap, d) gather buffer nor the (Q, nprobe·MB·BS)
    distance matrix ever exists in HBM.  Returns ``(dists (Q, n),
    vids (Q, n), pos (Q, n), live (Q, n))`` with n = pages·kpage; ``pos``
    is each candidate's pool position (``block_id·BS + slot``, -1 dead),
    which the exact rerank gathers from the cold tier.

    With the ``int8`` codec the dequant-fused kernel variants run instead:
    the probed posting's scale/zero ride the block-table DMA and the page
    is reconstructed on the VPU, so the page stream stays 1 byte/dim.

    ``schedule="per_query"`` streams every probed page once per query
    (paper-faithful ParallelGET).  ``schedule="batched"`` dedups the whole
    micro-batch's pages to a static ``scan_page_budget`` (overflow drops
    the highest-numbered pages — see ``ops.dedup_pages``) and scores each
    unique page against all queries with one MXU GEMM; candidates are then
    masked back to each query's own probe set, so results match the
    per-query schedule whenever the budget holds every unique page.
    """
    cfg = state.cfg
    pool = state.pool
    q, nprobe = pids.shape
    mb = pool.max_blocks_per_posting
    bs = pool.block_size
    kpage = min(k, pool.block_size)
    interp = cfg.pallas_interpret
    quant = pool.codec == "int8"
    flat = _page_table(state, pids, probe_valid)        # (Q, NB)
    # posting owning each page row: pages j of probe i are i*MB..i*MB+MB-1
    page_pid = jnp.repeat(pids, mb, axis=1)             # (Q, NB)
    safe_pp = jnp.maximum(page_pid, 0)

    if schedule == "per_query":
        pvids, live = _page_slot_live(state, flat)      # (Q, NB, BS)
        if quant:
            d, slots = scan_ops.scan_posting_blocks_topk_q8(
                queries, flat, live, pool.blocks,
                pool.post_scale[safe_pp], pool.post_zero[safe_pp],
                k=kpage, interpret=interp,
            )                                           # (Q, NB, kpage)
        else:
            d, slots = scan_ops.scan_posting_blocks_topk(
                queries, flat, live, pool.blocks, k=kpage, interpret=interp
            )                                           # (Q, NB, kpage)
        cand_v = jnp.take_along_axis(pvids, slots, axis=2)
        cand_p = jnp.where(
            (flat >= 0)[:, :, None], flat[:, :, None] * bs + slots, -1
        )
        cand_d = d.reshape(q, -1)
        cand_v = cand_v.reshape(q, -1)
        cand_p = cand_p.reshape(q, -1)
    elif schedule == "batched":
        budget = cfg.scan_page_budget or min(q * nprobe * mb, cfg.num_blocks)
        uniq, member_pos, _, _ = scan_ops.dedup_pages(
            flat.reshape(-1), budget=budget, num_blocks=cfg.num_blocks
        )
        pvids, live = _page_slot_live(state, uniq)      # (budget, BS)
        if quant:
            # invert the dedup: every original probe scatters its posting's
            # scale/zero onto its unique-page row (one posting owns each
            # block, so colliding writers carry identical values)
            fscale = pool.post_scale[safe_pp].reshape(-1)
            fzero = pool.post_zero[safe_pp].reshape(-1)
            tgt = jnp.where(member_pos >= 0, member_pos, budget)
            u_scale = jnp.ones((budget,), jnp.float32).at[tgt].set(
                fscale, mode="drop"
            )
            u_zero = jnp.zeros((budget,), jnp.float32).at[tgt].set(
                fzero, mode="drop"
            )
            d, slots = scan_ops.scan_unique_blocks_topk_q8(
                queries, uniq, live, pool.blocks, u_scale, u_zero,
                k=kpage, interpret=interp,
            )                                           # (budget, Q, kpage)
        else:
            d, slots = scan_ops.scan_unique_blocks_topk(
                queries, uniq, live, pool.blocks, k=kpage, interpret=interp
            )                                           # (budget, Q, kpage)
        page_v = jnp.take_along_axis(pvids[:, None, :], slots, axis=2)
        page_p = jnp.where(
            (uniq >= 0)[:, None, None], uniq[:, None, None] * bs + slots, -1
        )
        # gather each query's own probed pages back out of the unique-page
        # tiles (parity with the per-query schedule: a page another query
        # probed must not leak in) — the reduce then sees the per-query
        # (Q, NB, kpage) candidate shape, NOT (Q, budget, kpage)
        mp = member_pos.reshape(q, -1)                  # (Q, NB)
        safe_mp = jnp.maximum(mp, 0)
        qi = jnp.arange(q)[:, None]
        cand_d = jnp.where(
            (mp >= 0)[:, :, None], d[safe_mp, qi], MASK_DISTANCE
        ).reshape(q, -1)
        cand_v = page_v[safe_mp, qi].reshape(q, -1)
        cand_p = jnp.where(
            (mp >= 0)[:, :, None], page_p[safe_mp, qi], -1
        ).reshape(q, -1)
    else:
        raise ValueError(
            f"scan_schedule must be 'per_query' or 'batched', got {schedule!r}"
        )
    return cand_d, cand_v, cand_p, cand_d < MASK_DISTANCE / 2


@functools.partial(jax.jit, static_argnames=("nprobe", "scan_page_budget"))
def scan_page_stats(
    state: IndexState,
    queries: Array,
    *,
    nprobe: int | None = None,
    scan_page_budget: int | None = None,
) -> dict[str, Array]:
    """Batched-schedule page accounting for a query micro-batch.

    The search hot path cannot surface the dedup counters (it returns only
    ``(dists, vids)``), so overflow accounting lives here: run it on a
    representative micro-batch to size ``scan_page_budget`` and to watch
    for silent recall loss (``overflow > 0`` means the budget dropped
    probed pages).  ``benchmarks/run.py --json`` reports it per workload.

    Returns ``{"n_pages", "n_unique", "overflow"}`` (device scalars).
    """
    cfg = state.cfg
    nprobe = cfg.nprobe if nprobe is None else nprobe
    budget = scan_page_budget if scan_page_budget is not None \
        else cfg.scan_page_budget
    budget = budget or min(
        queries.shape[0] * nprobe * cfg.max_blocks_per_posting,
        cfg.num_blocks,
    )
    nav_d, pids = navigate(state, queries, nprobe)
    probe_valid = nav_d < MASK_DISTANCE / 2
    flat = _page_table(state, pids, probe_valid)
    _, _, n_unique, overflow = scan_ops.dedup_pages(
        flat.reshape(-1), budget=budget, num_blocks=cfg.num_blocks
    )
    return {
        "n_pages": jnp.sum(flat >= 0),
        "n_unique": n_unique,
        "overflow": overflow,
    }


def _posting_positions(pool, flat_pids: Array) -> Array:
    """Pool positions (``block_id·BS + slot``) of every capacity slot of
    the given postings: ``(m,)`` pids → ``(m, cap)``, -1 for absent
    blocks.  The rerank gathers exact payloads by these positions."""
    bids = pool.posting_blocks[flat_pids]               # (m, MB)
    slot = jnp.arange(pool.block_size, dtype=jnp.int32)
    pos = bids[..., None] * pool.block_size + slot[None, None, :]
    pos = jnp.where(bids[..., None] >= 0, pos, -1)
    return pos.reshape(flat_pids.shape[0], -1)


def _scan_probe_chunk(
    state: IndexState, queries: Array, pids: Array, probe_valid: Array
) -> tuple[Array, Array, Array, Array]:
    """Score one chunk of probed postings.  queries (Q, d); pids (Q, c).
    Returns (dists (Q, c*cap), vids, pos, live).

    Payloads come off the HOT tier (decoded through the posting codec) so
    the oracle computes the same distances as the dequant-fused Pallas
    scan — quantization error shows up identically on both data paths and
    the exact rerank removes it on both.
    """
    cfg = state.cfg
    q, c = pids.shape
    cap = cfg.posting_capacity
    flat_pids = jnp.maximum(pids.reshape(-1), 0)
    vecs, vids, vers, slot_valid = bp.parallel_get_hot(state.pool, flat_pids)
    pos = _posting_positions(state.pool, flat_pids)
    stale = vm.is_stale(state.versions, vids, vers)
    live = slot_valid & ~stale & probe_valid.reshape(-1)[:, None]
    vecs = vecs.reshape(q, c * cap, -1)
    vids = vids.reshape(q, c * cap)
    pos = pos.reshape(q, c * cap)
    live = live.reshape(q, c * cap)
    # scan math in cfg.scan_dtype (bf16 on TPU) with f32 accumulation —
    # halves the upcast traffic of int8 payloads (§Perf spfresh iter 2)
    sd = jnp.dtype(cfg.scan_dtype)
    qv = queries.astype(sd)
    xv = vecs.astype(sd)
    diff = qv[:, None, :] - xv
    dists = jnp.sum(
        (diff * diff).astype(jnp.float32), axis=-1
    )
    return dists, vids, pos, live


def _rerank_exact(
    state: IndexState, queries: Array, cand_d: Array, cand_v: Array,
    cand_pos: Array, k: int,
) -> tuple[Array, Array]:
    """Exact fp32 rerank of an over-fetched, already-deduped candidate set.

    ``cand_pos (Q, k')`` are pool positions; the cold exact tier is
    gathered (k'·d fp32 values per query — tiny next to the scan) and the
    final top-k runs on true distances.  Candidates arrive vid-deduped,
    so a plain top_k suffices.
    """
    pool = state.pool
    tier = pool.blocks_exact if pool.blocks_exact is not None else pool.blocks
    flat = tier.reshape(-1, pool.dim)
    safe = jnp.maximum(cand_pos, 0)
    vecs = flat[safe].astype(jnp.float32)               # (Q, k', d)
    qf = queries.astype(jnp.float32)
    diff = vecs - qf[:, None, :]
    dist = jnp.sum(diff * diff, axis=-1)
    dist = jnp.where((cand_pos >= 0) & (cand_v >= 0), dist, MASK_DISTANCE)
    neg, sel = jax.lax.top_k(-dist, k)
    top_d = -neg
    out_v = jnp.where(
        top_d < MASK_DISTANCE / 2,
        jnp.take_along_axis(cand_v, sel, axis=1),
        -1,
    )
    return top_d, out_v


def scan_and_reduce(
    state: IndexState,
    queries: Array,
    pids: Array,
    probe_valid: Array,
    *,
    k: int,
    probe_chunk: int = 0,
    use_pallas_scan: bool | None = None,
    scan_schedule: str | None = None,
) -> tuple[Array, Array]:
    """Posting scan + dedup top-k over an already-navigated probe set.

    Shared by ``search`` and the grouped two-level search; the scan data
    path is selected here:

    * **Pallas paged scan** (``use_pallas_scan``, schedule per
      ``scan_schedule`` — both default to the config flags): pages stream
      HBM→VMEM through the ``posting_scan`` kernels, which emit per-page
      k-min candidates; the reduce then works on (Q, pages·kpage)
      candidates.  ``probe_chunk`` is ignored — the kernel grid already
      streams page-at-a-time, and the candidate buffer is k-reduced.
    * **XLA gather oracle** (default): ``bp.parallel_get_hot`` materializes
      the (Q, nprobe·cap, d) probe buffer (decoded hot tier);
      ``probe_chunk > 0`` processes the probes in chunks with a running
      candidate set so the buffer is O(Q · chunk · cap · d).

    With a lossy codec and ``cfg.rerank_factor > 1``, both data paths
    over-fetch ``rerank_factor × k`` deduped candidates from the
    quantized scan, then rerank them against the cold exact-fp32 tier
    before the final top-k (the two-tier search closing the accuracy
    gap).
    """
    cfg = state.cfg
    q, nprobe = pids.shape
    cap = cfg.posting_capacity
    pallas = cfg.use_pallas_scan if use_pallas_scan is None else use_pallas_scan
    schedule = scan_schedule if scan_schedule is not None else cfg.scan_schedule
    rerank = cfg.rerank_factor > 1 and state.pool.blocks_exact is not None
    kq = k * cfg.rerank_factor if rerank else k

    def reduce_and_rerank(cand_d, cand_v, cand_p, live):
        n = cand_d.shape[1]
        kk = min(kq, n) if rerank else k
        m = _dedup_prefilter(cfg, kk, n)
        d, v, oi = jax.vmap(
            lambda dd, vv, mm: _dedup_topk_1d_full(dd, vv, mm, kk, m)
        )(cand_d, cand_v, live)
        if not rerank:
            return d, v
        pos = jnp.take_along_axis(cand_p, jnp.maximum(oi, 0), axis=1)
        pos = jnp.where(oi >= 0, pos, -1)
        return _rerank_exact(state, queries, d, v, pos, k)

    if pallas:
        cand_d, cand_v, cand_p, live = _pallas_scan_candidates(
            state, queries, pids, probe_valid, k=kq, schedule=schedule
        )
        return reduce_and_rerank(cand_d, cand_v, cand_p, live)

    if probe_chunk <= 0 or nprobe % probe_chunk != 0 or nprobe == probe_chunk:
        dists, vids, pos, live = _scan_probe_chunk(
            state, queries, pids, probe_valid
        )
        return reduce_and_rerank(dists, vids, pos, live)

    nc = nprobe // probe_chunk
    keep = min(max(4 * kq, 64), probe_chunk * cap)
    pids_c = pids.reshape(q, nc, probe_chunk).transpose(1, 0, 2)
    pvalid_c = probe_valid.reshape(q, nc, probe_chunk).transpose(1, 0, 2)

    def body(carry, inp):
        best_d, best_v, best_p = carry  # (Q, keep)
        pc, vc = inp
        d, v, p, live = _scan_probe_chunk(state, queries, pc, vc)
        d = jnp.where(live, d, MASK_DISTANCE)
        cat_d = jnp.concatenate([best_d, d], axis=1)
        cat_v = jnp.concatenate([best_v, v], axis=1)
        cat_p = jnp.concatenate([best_p, p], axis=1)
        neg, sel = jax.lax.top_k(-cat_d, keep)
        return (
            -neg,
            jnp.take_along_axis(cat_v, sel, axis=1),
            jnp.take_along_axis(cat_p, sel, axis=1),
        ), None

    init = (
        jnp.full((q, keep), MASK_DISTANCE, jnp.float32),
        jnp.full((q, keep), -1, jnp.int32),
        jnp.full((q, keep), -1, jnp.int32),
    )
    (best_d, best_v, best_p), _ = jax.lax.scan(body, init, (pids_c, pvalid_c))
    live = best_d < MASK_DISTANCE / 2
    return reduce_and_rerank(best_d, best_v, best_p, live)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "nprobe", "probe_chunk", "use_pallas_scan", "scan_schedule",
        "with_access",
    ),
)
def search(
    state: IndexState,
    queries: Array,
    *,
    k: int,
    nprobe: int | None = None,
    probe_chunk: int = 0,
    use_pallas_scan: bool | None = None,
    scan_schedule: str | None = None,
    with_access: bool = False,
    qvalid: Array | None = None,
) -> tuple[Array, ...]:
    """ANN search: centroid navigation → posting scan → dedup top-k.

    Returns ``(dists (Q, k), vids (Q, k))``; missing results are ``-1`` with
    MASK_DISTANCE.  ``nprobe`` is the latency-budget knob (the paper's 10 ms
    hard cut becomes a fixed candidate budget under jit).

    The posting-scan data path (Pallas paged streaming vs XLA gather, and
    the per-query vs batch-dedup page schedule) is selected by
    ``use_pallas_scan`` / ``scan_schedule`` — ``None`` defers to the
    config flags.  See ``scan_and_reduce`` for the probe_chunk semantics
    of the oracle path.

    ``with_access=True`` additionally returns the per-posting probe
    histogram (``probe_histogram``) as a third output; the ``(dists,
    vids)`` numerics are untouched.  ``qvalid (Q,)`` masks padded query
    rows out of the histogram ONLY (their dists/vids rows are computed
    regardless and discarded by the caller, as before).
    """
    cfg = state.cfg
    nprobe = cfg.nprobe if nprobe is None else nprobe
    nav_d, pids = navigate(state, queries, nprobe)  # (Q, nprobe)
    probe_valid = nav_d < MASK_DISTANCE / 2
    d, v = scan_and_reduce(
        state, queries, pids, probe_valid,
        k=k, probe_chunk=probe_chunk,
        use_pallas_scan=use_pallas_scan, scan_schedule=scan_schedule,
    )
    if not with_access:
        return d, v
    counted = probe_valid if qvalid is None else probe_valid & qvalid[:, None]
    return d, v, probe_histogram(cfg, pids, counted)


# ---------------------------------------------------------------------------
# Reassignment execution (shared by split and merge)
# ---------------------------------------------------------------------------

def _dedup_vid_mask_ref(vids: Array, mask: Array) -> Array:
    """Reference same-vid dedup (the original O(n²) pairwise mask, kept as
    the oracle for tests and the before/after benchmark): a masked row is
    dropped when any earlier-indexed masked row carries the same vid."""
    n = vids.shape[0]
    idx = jnp.arange(n)
    same = (vids[:, None] == vids[None, :]) & (
        idx[:, None] > idx[None, :]
    )
    dup = jnp.any(same & mask[None, :], axis=1)
    return mask & ~dup


def _dedup_vid_mask(vids: Array, mask: Array) -> Array:
    """First-occurrence-per-vid filter over the masked rows.

    Sort-based idiom (the `_dedup_topk_1d` rewrite applied to the reassign
    batch): one stable argsort on a masked key instead of the O(n²)
    pairwise comparison matrix.  Unmasked rows key to a sentinel so they
    never suppress a masked row; within a vid group the stable sort keeps
    the lowest original index — exactly the reference semantics.
    """
    n = vids.shape[0]
    key = jnp.where(mask, vids, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(key, stable=True)
    sk = key[order]
    first = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    return mask & jnp.zeros((n,), bool).at[order].set(first)


def _execute_reassigns(
    state: IndexState,
    cand_vecs: Array,   # (C, d)
    cand_vids: Array,   # (C,)
    cand_cur_pid: Array,  # (C,) posting the candidate currently lives in
    cand_mask: Array,   # (C,) passed the necessary conditions
    budget: int | None = None,
) -> IndexState:
    """Paper §3.3 final stage: per candidate, search the new closest posting,
    NPA-recheck to drop false positives, then version-bump + re-append.

    Candidates are compacted to ``budget`` rows (default
    ``cfg.reassign_budget``; overflow counted — the paper reports ~79
    actual reassigns out of ~5094 evaluated, so the budget is generous).
    The maintenance round concatenates EVERY job's candidates into one
    call here with a jobs-scaled budget, so the whole round pays one
    routing GEMM and one `append_scatter` instead of two per job.
    """
    cfg = state.cfg
    c = cand_vecs.shape[0]
    budget = min(budget or cfg.reassign_budget, c)

    # --- compact to the evaluation budget ---
    order = jnp.argsort(~cand_mask, stable=True)  # True (mask) rows first
    take = order[:budget]
    vecs = cand_vecs[take]
    vids = cand_vids[take]
    cur_pid = cand_cur_pid[take]
    mask = cand_mask[take]
    n_cand = jnp.sum(cand_mask)
    overflow = jnp.maximum(n_cand - budget, 0)

    # --- dedup same vid within the batch (concurrent-reassign CAS analogue) ---
    mask = _dedup_vid_mask(vids, mask)
    # Deleted/stale ids never get reassigned (they get GC'd instead).
    mask = mask & ~vm.is_deleted(state.versions, jnp.maximum(vids, 0)) & (vids >= 0)

    # --- NPA re-check: find the true nearest posting now ---
    # The re-check only needs the argmin posting, not the full top-R
    # closure routing — a masked argmin over the (budget × P) GEMM, so the
    # (sort-backed, CPU-hostile) masked top-k runs only on the compacted
    # movers below.
    d_all = pairwise_sql2(vecs, state.centroids, state.centroid_sqn)
    d_all = jnp.where(state.centroid_valid[None, :], d_all, MASK_DISTANCE)
    nearest = jnp.argmin(d_all, axis=1).astype(jnp.int32)
    nearest = jnp.where(
        jnp.min(d_all, axis=1) < MASK_DISTANCE / 2, nearest, -1
    )
    # False-positive filter (paper: "if a vector actually does not need
    # reassignment, the reassign operation is aborted"): if a LIVE replica of
    # this vid already sits in the nearest posting, NPA is satisfied.
    safe_vids = jnp.maximum(vids, 0)
    cur_ver = state.versions[safe_vids] & vm.VERSION_MASK
    t_vids, t_vers, t_valid = jax.vmap(
        lambda p: bp.gather_posting_ids(state.pool, p)
    )(jnp.maximum(nearest, 0))  # (budget, cap)
    replica_there = jnp.any(
        (t_vids == vids[:, None])
        & t_valid
        & ((t_vers & vm.VERSION_MASK) == cur_ver[:, None]),
        axis=-1,
    )
    need = mask & (nearest >= 0) & (nearest != cur_pid) & ~replica_there

    # --- compact the actual MOVERS to reassign_budget candidates ---
    # The paper reports ~79 movers out of ~5094 evaluated, so the write
    # path is sized for the movers, not the evaluation budget: the fused
    # round evaluates its jobs-scaled candidate budget with the GEMMs
    # above, but at most reassign_budget vectors move per pass (the knob's
    # original meaning) — keeping the append scatter, the scarcest op on
    # CPU/TPU alike, at a fixed small row count.  Truncated movers simply
    # stay where they are (counted as overflow; live replicas untouched).
    movers = min(cfg.reassign_budget, budget)
    morder = jnp.argsort(~need, stable=True)
    mtake = morder[:movers]
    m_vecs = vecs[mtake]
    m_vids = vids[mtake]
    m_safe_vids = safe_vids[mtake]
    m_cur_ver = cur_ver[mtake]
    m_need = need[mtake]
    n_need = jnp.sum(need)
    overflow = overflow + jnp.maximum(n_need - movers, 0)
    # Full closure routing (top-R + replica rule) for the movers only.
    m_pids, _, m_replica_ok = route(state, m_vecs, cfg.replica_count)

    # --- append fresh replicas at the new homes with a TENTATIVE version ---
    # The version map is only bumped if the primary append lands; otherwise
    # the old replicas stay live (no data loss when the target is full) and
    # the tentative appends are stale garbage, GC'd by the next split.
    tentative_ver = (m_cur_ver + 1) & vm.VERSION_MASK
    enable = m_need[:, None] & m_replica_ok & (m_pids >= 0)
    flat_pids = jnp.maximum(m_pids.reshape(-1), 0)
    flat_enable = enable.reshape(-1)
    flat_vecs = jnp.repeat(m_vecs, cfg.replica_count, axis=0)
    flat_vids = jnp.repeat(m_vids, cfg.replica_count)
    flat_vers = jnp.repeat(tentative_ver, cfg.replica_count)
    # collision-ranked scatter append: the whole (movers·R)-row batch lands
    # in one dispatch instead of a movers·R-step tail-write scan
    pool, oks = bp.append_scatter(
        state.pool, flat_pids, flat_vecs, flat_vids, flat_vers, flat_enable
    )
    landed = oks.reshape(-1, cfg.replica_count)[:, 0]
    commit = m_need & landed
    versions = vm.bump_version(state.versions, m_safe_vids, commit)
    telemetry = _bump_append_telemetry(state, flat_pids, flat_vecs, oks)
    state = state.replace(versions=versions, telemetry=telemetry)

    stats = state.stats
    stats = bump_stat(stats, "n_reassign_candidates", n_cand)
    stats = bump_stat(stats, "n_reassign_overflow", overflow)
    stats = bump_stat(stats, "n_reassigned", jnp.sum(commit))
    stats = bump_stat(stats, "n_appends", jnp.sum(oks))
    stats = bump_stat(
        stats, "n_append_drops", jnp.sum(flat_enable) - jnp.sum(oks)
    )
    return state.replace(pool=pool, stats=stats)


# ---------------------------------------------------------------------------
# Split (Local Rebuilder job, §4.2.1) — batched K-job core + K=1 wrapper
# ---------------------------------------------------------------------------

def _split_jobs(
    state: IndexState, pids: Array, enable: Array
) -> tuple[IndexState, Array, tuple[Array, Array, Array, Array]]:
    """K split jobs in one fused pass.  ``pids (K,)`` must be distinct.

    Per job: GC the posting; if still oversized, balanced-2-means split
    into two fresh postings.  All K jobs share one vmapped
    `balanced_two_means`, one batched pid alloc, one `free_postings`
    scatter, ONE `put_postings` scatter for every half-write and GC
    write-back, and one ``(K × P)`` neighbor GEMM.

    Returns ``(state, acted (K,), (cand_vecs, cand_vids, cand_cur,
    cand_mask))`` — the flattened reassign candidates
    (``K·(1+reassign_range)·cap`` rows) for the caller's fused
    `_execute_reassigns`.
    """
    cfg = state.cfg
    cap = cfg.posting_capacity
    k = pids.shape[0]
    pids = pids.astype(jnp.int32)
    safe = jnp.maximum(pids, 0)
    enable = enable & (pids >= 0) & state.centroid_valid[safe]

    vecs, vids, vers, valid = bp.gather_postings(state.pool, safe)  # (K, cap, ...)
    live = valid & ~vm.is_stale(state.versions, vids, vers)
    n_live = jnp.sum(live, axis=1)                       # (K,)
    cur_len = state.pool.posting_len[safe]
    cur_ver = state.versions[jnp.maximum(vids, 0)] & vm.VERSION_MASK

    # ---- Case A: garbage-collection write-back resolves the job ----
    gc_wb = enable & (n_live <= cfg.split_limit) & (n_live < cur_len)
    order_live = jnp.argsort(~live, axis=1, stable=True)
    gc_vecs = jnp.take_along_axis(vecs, order_live[..., None], axis=1)
    gc_vids = jnp.take_along_axis(vids, order_live, axis=1)
    gc_vers = jnp.take_along_axis(cur_ver, order_live, axis=1)

    # ---- Case B: real split ----
    want = enable & (n_live > cfg.split_limit)
    if not cfg.enable_split:
        want = jnp.zeros_like(want)
    rng, sub = jax.random.split(state.rng)
    state = state.replace(rng=rng)
    new_centroids, assign = jax.vmap(
        lambda key, x, lv: balanced_two_means(
            key, x, lv, iters=cfg.kmeans_iters
        )
    )(jax.random.split(sub, k), vecs.astype(jnp.float32), live)
    # new_centroids (K, 2, d); assign (K, cap) in {-1, 0, 1}

    state, new_pids = alloc_pids(state, jnp.repeat(want, 2))  # (2K,)
    pid1, pid2 = new_pids[0::2], new_pids[1::2]
    ok = want & (pid1 >= 0) & (pid2 >= 0)
    # Roll back half-successful allocations (pid1 landed, pid2 didn't).
    state = free_pids(state, new_pids, jnp.repeat(want & ~ok, 2))

    old_centroid = state.centroids[safe]                 # (K, d)
    old_access = state.telemetry.access_count[safe]      # (K,) read pre-free

    # Retire the old postings (blocks + centroids + ids) in one scatter.
    pool = bp.free_postings(state.pool, safe, ok)
    state = state.replace(pool=pool)
    state = free_pids(state, pids, ok)

    # Halves, compacted to the front of fixed-capacity buffers.
    in0 = live & (assign == 0)
    in1 = live & (assign == 1)
    n0 = jnp.sum(in0, axis=1)
    n1 = jnp.sum(in1, axis=1)
    order0 = jnp.argsort(~in0, axis=1, stable=True)
    order1 = jnp.argsort(~in1, axis=1, stable=True)

    def _take(buf, order):
        if buf.ndim == 3:
            return jnp.take_along_axis(buf, order[..., None], axis=1)
        return jnp.take_along_axis(buf, order, axis=1)

    # ONE put scatter: K GC write-backs (old pid) + 2K half-writes (fresh
    # pids) — all target pids distinct among enabled rows.
    put_pids = jnp.concatenate([safe, jnp.maximum(pid1, 0), jnp.maximum(pid2, 0)])
    put_vecs = jnp.concatenate(
        [gc_vecs, _take(vecs, order0), _take(vecs, order1)], axis=0
    )
    put_vids = jnp.concatenate(
        [gc_vids, _take(vids, order0), _take(vids, order1)], axis=0
    )
    put_vers = jnp.concatenate(
        [gc_vers, _take(cur_ver, order0), _take(cur_ver, order1)], axis=0
    )
    put_ns = jnp.concatenate([n_live, n0, n1])
    put_en = jnp.concatenate([gc_wb, ok, ok])
    pool, _ = bp.put_postings(
        state.pool, put_pids, put_vecs, put_vids, put_vers, put_ns, put_en
    )
    state = state.replace(pool=pool)
    state = set_centroids(state, pid1, new_centroids[:, 0], ok)
    state = set_centroids(state, pid2, new_centroids[:, 1], ok)

    # Telemetry transfer: the two fresh halves inherit the split posting's
    # access count proportionally to their live sizes (integer shares that
    # conserve the total exactly); update_count/drift_vec measure "since
    # last split", so the halves restart at zero — fresh pids come off the
    # free stack already zeroed (`free_pids`).
    tot = jnp.maximum(n0 + n1, 1)
    share1 = (old_access * n0) // tot
    share2 = old_access - share1
    cap_p = cfg.num_postings_cap
    t1 = jnp.where(ok, jnp.maximum(pid1, 0), cap_p)
    t2 = jnp.where(ok, jnp.maximum(pid2, 0), cap_p)
    acc = state.telemetry.access_count.at[t1].set(share1, mode="drop")
    acc = acc.at[t2].set(share2, mode="drop")
    state = state.replace(
        telemetry=state.telemetry.replace(access_count=acc)
    )

    # ---- Reassignment candidates (the heart of LIRE) ----
    # Neighbors: reassign_range nearest postings to each *old* centroid,
    # excluding the job's own two fresh halves — one (K × P) GEMM instead
    # of K skinny (1 × P) ones.
    nb_d = pairwise_sql2(old_centroid, state.centroids, state.centroid_sqn)
    arange_p = jnp.arange(cfg.num_postings_cap)
    nb_valid = (
        state.centroid_valid[None, :]
        & (arange_p[None, :] != jnp.maximum(pid1, 0)[:, None])
        & (arange_p[None, :] != jnp.maximum(pid2, 0)[:, None])
    )
    nb_dist, nb_pids = masked_topk(nb_d, nb_valid, cfg.reassign_range)
    nb_ok = nb_dist < MASK_DISTANCE / 2                  # (K, RR)

    nvecs, nvids, nvers, nvalid = bp.gather_postings(
        state.pool, nb_pids.reshape(-1)
    )  # (K·RR, cap, ...)
    nlive = nvalid & ~vm.is_stale(state.versions, nvids, nvers)
    nlive = nlive & nb_ok.reshape(-1)[:, None] & jnp.repeat(ok, cfg.reassign_range)[:, None]

    # Eq. (2) for neighbor vectors; Eq. (1) for the split posting's vectors.
    eq2 = jax.vmap(npa.split_neighbor_candidates)(
        nvecs.reshape(k, -1, cfg.dim).astype(jnp.float32),
        old_centroid,
        new_centroids,
    ).reshape(k * cfg.reassign_range, cap)
    eq1 = jax.vmap(npa.split_old_posting_candidates)(
        vecs.astype(jnp.float32), old_centroid, new_centroids
    )  # (K, cap)
    own_cur = jnp.where(
        assign == 0, jnp.maximum(pid1, 0)[:, None], jnp.maximum(pid2, 0)[:, None]
    )

    cand_vecs = jnp.concatenate(
        [vecs.reshape(-1, cfg.dim), nvecs.reshape(-1, cfg.dim)], axis=0
    )
    cand_vids = jnp.concatenate([vids.reshape(-1), nvids.reshape(-1)])
    cand_cur = jnp.concatenate(
        [own_cur.reshape(-1), jnp.repeat(nb_pids.reshape(-1), cap)]
    )
    cand_mask = jnp.concatenate(
        [(eq1 & live & ok[:, None]).reshape(-1), (eq2 & nlive).reshape(-1)]
    )

    checked = jnp.sum(jnp.where(ok, n_live, 0)) + jnp.sum(nlive)
    stats = bump_stat(state.stats, "n_reassign_checked", checked)
    stats = bump_stat(stats, "n_splits", jnp.sum(ok))
    stats = bump_stat(stats, "n_gc_writebacks", jnp.sum(gc_wb))
    state = state.replace(stats=stats, step=state.step + 1)
    return state, (ok | gc_wb), (cand_vecs, cand_vids, cand_cur, cand_mask)


@jax.jit
def split_posting(
    state: IndexState, pid: Array, enable: Array
) -> tuple[IndexState, Array]:
    """Split job: GC the posting; if still oversized, balanced-2-means split,
    then LIRE reassignment over the split + ``reassign_range`` neighbors.

    K=1 wrapper over the batched `_split_jobs` core (the maintenance round
    runs K of these fused); returns ``(state, acted)`` where acted covers
    both GC-writeback and true splits.
    """
    pid = jnp.asarray(pid, jnp.int32).reshape(1)
    enable = jnp.asarray(enable).reshape(1)
    state, acted, cand = _split_jobs(state, pid, enable)
    if state.cfg.enable_reassign:
        state = _execute_reassigns(state, *cand)
    return state, acted[0]


# ---------------------------------------------------------------------------
# Merge (Local Rebuilder job, §3.2 / §4.2.1) — batched K-job core + wrapper
# ---------------------------------------------------------------------------

def _merge_jobs(
    state: IndexState, pids: Array, enable: Array, exclude_pids: Array
) -> tuple[IndexState, Array, tuple[Array, Array, Array, Array]]:
    """K merge jobs in one fused pass.  ``pids (K,)`` must be distinct.

    Target selection (nearest of the ``merge_fanout`` closest postings with
    room) is one ``(K × P)`` GEMM; the moves land through ONE
    `append_scatter` over the K·cap concatenated rows, whose per-posting
    collision ranks keep per-append capacity safety when two jobs pick the
    same target.  ``exclude_pids`` are barred as targets — the round
    passes every merge source, since a source freed later in the round
    must not absorb another job's vectors.

    Returns ``(state, gone (K,), (cand_vecs, cand_vids, cand_cur,
    cand_mask))`` — the moved vectors as reassign candidates.
    """
    cfg = state.cfg
    k = pids.shape[0]
    pids = pids.astype(jnp.int32)
    safe = jnp.maximum(pids, 0)
    enable = enable & (pids >= 0) & state.centroid_valid[safe]

    vecs, vids, vers, valid = bp.gather_postings(state.pool, safe)
    live = valid & ~vm.is_stale(state.versions, vids, vers)
    n_live = jnp.sum(live, axis=1)                       # (K,)
    enable = enable & (n_live < cfg.merge_limit)

    # Nearest postings able to absorb each job: try the merge_fanout closest.
    own_centroid = state.centroids[safe]                 # (K, d)
    d = pairwise_sql2(own_centroid, state.centroids, state.centroid_sqn)
    arange_p = jnp.arange(cfg.num_postings_cap)
    ex = exclude_pids.astype(jnp.int32)
    excluded = jnp.any(
        (arange_p[:, None] == ex[None, :]) & (ex >= 0)[None, :], axis=1
    )
    cand_ok = state.centroid_valid & ~excluded           # (P,)
    cd, cpids = masked_topk(
        d, jnp.broadcast_to(cand_ok[None, :], d.shape), cfg.merge_fanout
    )
    fits = (cd < MASK_DISTANCE / 2) & (
        state.pool.posting_len[jnp.maximum(cpids, 0)] + n_live[:, None]
        <= cfg.posting_capacity
    )
    any_fit = jnp.any(fits, axis=1)
    first_fit = jnp.argmax(fits, axis=1)                 # first True per job
    target = jnp.where(
        any_fit, jnp.take_along_axis(cpids, first_fit[:, None], axis=1)[:, 0], -1
    )
    do = enable & any_fit & (n_live > 0)
    # Shared-target capacity: `fits` was checked against the pre-append
    # lengths, so two jobs absorbing into the same posting could together
    # overflow it and leak a partially-landed (live, unreclaimable) copy.
    # Charge each job the load of every EARLIER move candidate on the same
    # target (conservative: earlier candidates later dropped still count)
    # and defer jobs that no longer fit to the next round.
    jidx = jnp.arange(k)
    same_t = (target[:, None] == target[None, :]) & (target >= 0)[:, None]
    prior = jnp.sum(
        jnp.where(
            same_t & (jidx[:, None] > jidx[None, :]) & do[None, :],
            n_live[None, :], 0,
        ),
        axis=1,
    )
    do = do & (
        state.pool.posting_len[jnp.maximum(target, 0)] + prior + n_live
        <= cfg.posting_capacity
    )
    # Empty postings are simply retired.
    retire_empty = enable & (n_live == 0)

    cur_ver = state.versions[jnp.maximum(vids, 0)] & vm.VERSION_MASK
    move = live & do[:, None]
    tgt_rows = jnp.broadcast_to(jnp.maximum(target, 0)[:, None], (k, vecs.shape[1]))
    pool, oks = bp.append_scatter(
        state.pool,
        tgt_rows.reshape(-1),
        vecs.reshape(-1, cfg.dim),
        vids.reshape(-1),
        cur_ver.reshape(-1),
        move.reshape(-1),
    )
    state = state.replace(pool=pool)

    # Retire the merged-away postings — only where every live vector landed
    # in the target (pool OOM mid-merge must not lose vectors).
    all_moved = jnp.all(oks.reshape(k, -1) == move, axis=1)
    do = do & all_moved
    gone = do | retire_empty

    # Telemetry: the moves are fresh appends on the target (+1 update,
    # += displacement vs the TARGET centroid, which a merge never moves);
    # an absorbed source's access count transfers into its target — a
    # scatter-add, since two jobs may share one target — BEFORE the source
    # pid is freed (free_pids zeroes the source rows).  retire_empty
    # sources have nothing left to describe; their access just drops.
    tel = _bump_append_telemetry(
        state, tgt_rows.reshape(-1), vecs.reshape(-1, cfg.dim), oks
    )
    src_access = tel.access_count[safe]
    t_acc = jnp.where(do, jnp.maximum(target, 0), cfg.num_postings_cap)
    tel = tel.replace(
        access_count=tel.access_count.at[t_acc].add(
            jnp.where(do, src_access, 0), mode="drop"
        )
    )
    state = state.replace(telemetry=tel)

    pool = bp.free_postings(state.pool, safe, gone)
    state = state.replace(pool=pool)
    state = free_pids(state, pids, gone)

    # Reassign check over moved vectors only (no neighbor scan for merges).
    state = state.replace(
        stats=bump_stat(
            bump_stat(state.stats, "n_merges", jnp.sum(do)),
            "n_reassign_checked", jnp.sum(jnp.where(do, n_live, 0)),
        ),
        step=state.step + 1,
    )
    cand_cur = tgt_rows.reshape(-1)
    cand_mask = (live & do[:, None]).reshape(-1)
    return state, gone, (
        vecs.reshape(-1, cfg.dim), vids.reshape(-1), cand_cur, cand_mask
    )


@jax.jit
def merge_posting(
    state: IndexState, pid: Array, enable: Array
) -> tuple[IndexState, Array]:
    """Merge job: append the undersized posting's live vectors into the
    nearest posting that can hold them, delete its centroid, then run the
    (neighbor-free) reassignment check over the moved vectors.

    K=1 wrapper over the batched `_merge_jobs` core.
    """
    pid = jnp.asarray(pid, jnp.int32).reshape(1)
    enable = jnp.asarray(enable).reshape(1)
    state, gone, cand = _merge_jobs(state, pid, enable, pid)
    if state.cfg.enable_reassign:
        state = _execute_reassigns(state, *cand)
    return state, gone[0]


# ---------------------------------------------------------------------------
# Maintenance driver (the Local Rebuilder queue, discovered by length scan)
# ---------------------------------------------------------------------------

@jax.jit
def maintenance_step(state: IndexState) -> tuple[IndexState, Array]:
    """One background rebuild step: split the most oversized posting (if
    any), merge the most undersized (if any).  Constant work; returns
    ``(state, did_work)``.

    The §3.4 convergence argument bounds how many steps a driver loop needs:
    each split consumes a free posting id, so ``P_cap`` is a hard bound on
    cascade length.  `maintenance_round` is the batched K-job form.
    """
    cfg = state.cfg
    lens = state.pool.posting_len
    valid = state.centroid_valid

    split_scores = jnp.where(valid, lens, -1)
    split_pid = jnp.argmax(split_scores).astype(jnp.int32)
    want_split = split_scores[split_pid] > cfg.split_limit
    state, split_acted = split_posting(state, split_pid, want_split)

    merge_scores = jnp.where(
        valid & (lens < cfg.merge_limit), lens, jnp.iinfo(jnp.int32).max
    )
    merge_pid = jnp.argmin(merge_scores).astype(jnp.int32)
    want_merge = merge_scores[merge_pid] < cfg.merge_limit
    if not cfg.enable_merge:
        want_merge = jnp.asarray(False)
    state, merge_acted = merge_posting(state, merge_pid, want_merge)

    return state, (split_acted | merge_acted)


def _select_jobs(
    state: IndexState, k: int
) -> tuple[Array, Array, Array, Array]:
    """Job selection for one maintenance round, per ``cfg.maintain_policy``.

    ``"size"`` is the original selection, kept **bit-identical**: top-K
    longest postings split, bottom-K shortest merge.  ``"drift"`` is the
    Ada-IVF-style cost model over the telemetry leaves: *eligibility* is
    unchanged (only oversized postings may split, only undersized merge),
    but the *ranking* among eligible postings weighs access rate and
    centroid drift —

    * split priority = ``imbalance · (1 + alpha·access_rate) +
      beta·drift_rel`` where ``imbalance = len/split_limit``,
      ``access_rate`` is the posting's share of probes normalized so a
      uniformly-probed index scores 1 everywhere, and ``drift_rel`` is the
      mean displacement of appends since the last split relative to the
      centroid norm;
    * merge priority = ``len · (1 + alpha·access_rate)`` ascending —
      coldest+smallest first, so rarely-read runts are compacted before
      hot ones whose vectors searches still want cheap to find.

    With all-zero telemetry both formulas reduce to a monotone function of
    ``len`` — the drift policy cold-starts to the size ordering exactly
    (including ``top_k``'s lowest-index tie-breaking).

    Returns ``(split_pids, split_enable, merge_pids, merge_enable)``.
    """
    cfg = state.cfg
    lens = state.pool.posting_len
    valid = state.centroid_valid

    if cfg.maintain_policy == "size":
        # One length scan selects both job sets.
        split_scores = jnp.where(valid, lens, -1)
        top_l, split_pids = jax.lax.top_k(split_scores, k)
        split_enable = top_l > cfg.split_limit

        merge_scores = jnp.where(
            valid & (lens < cfg.merge_limit), lens, jnp.iinfo(jnp.int32).max
        )
        neg_l, merge_pids = jax.lax.top_k(-merge_scores, k)
        merge_enable = (-neg_l) < cfg.merge_limit
        return split_pids, split_enable, merge_pids, merge_enable

    tel = state.telemetry
    alpha = jnp.float32(cfg.maintain_alpha)
    beta = jnp.float32(cfg.maintain_beta)
    lens_f = lens.astype(jnp.float32)
    acc = jnp.where(valid, tel.access_count, 0).astype(jnp.float32)
    n_valid = jnp.sum(valid.astype(jnp.int32)).astype(jnp.float32)
    access_rate = acc * n_valid / jnp.maximum(jnp.sum(acc), 1.0)
    mean_disp = jnp.linalg.norm(tel.drift_vec, axis=-1) / jnp.maximum(
        tel.update_count.astype(jnp.float32), 1.0
    )
    drift_rel = mean_disp / jnp.sqrt(state.centroid_sqn + 1e-6)

    imbalance = lens_f / jnp.float32(cfg.split_limit)
    split_pri = imbalance * (1.0 + alpha * access_rate) + beta * drift_rel
    s_scores = jnp.where(
        valid & (lens > cfg.split_limit), split_pri, -jnp.inf
    )
    top_s, split_pids = jax.lax.top_k(s_scores, k)
    split_enable = top_s > -jnp.inf

    merge_pri = lens_f * (1.0 + alpha * access_rate)
    m_scores = jnp.where(
        valid & (lens < cfg.merge_limit), merge_pri, jnp.inf
    )
    neg_m, merge_pids = jax.lax.top_k(-m_scores, k)
    merge_enable = -neg_m < jnp.inf
    return split_pids, split_enable, merge_pids, merge_enable


@functools.partial(jax.jit, static_argnames=("jobs_per_round",))
def maintenance_round(
    state: IndexState,
    jobs_per_round: int | None = None,
    access: Array | None = None,
) -> tuple[IndexState, Array]:
    """One batched rebuild round: K split + K merge jobs selected by
    ``cfg.maintain_policy`` (see `_select_jobs`; disjoint pid sets —
    ``merge_limit < split_limit``), then every job's reassign candidates
    are concatenated into ONE `_execute_reassigns` call — one ``route``
    GEMM and one ``append_batch`` for the whole round instead of two per
    job.

    Returns ``(state, n_did_work)`` — the number of jobs that acted, ONE
    device scalar for the host drain loop to read back per round (the
    sequential driver synced on a bool per step).  ``jobs_per_round=None``
    defers to ``cfg.jobs_per_round``.

    ``access`` is an optional ``(P_cap,) i32`` probe histogram (the
    serving backend's host-accumulated search telemetry, WAL-logged with
    this dispatch) folded into ``telemetry.access_count`` BEFORE
    selection.  ``None`` skips the fold entirely — an empty pytree keys
    its own jit cache entry, so pre-telemetry call sites and old WAL
    records trace byte-identical graphs.
    """
    cfg = state.cfg
    k = int(jobs_per_round or cfg.jobs_per_round)
    k = max(1, min(k, cfg.num_postings_cap // 2))

    if access is not None:
        tel = state.telemetry
        state = state.replace(
            telemetry=tel.replace(
                access_count=tel.access_count + access.astype(jnp.int32)
            )
        )

    split_pids, split_enable, merge_pids, merge_enable = _select_jobs(state, k)
    if not cfg.enable_merge:
        merge_enable = jnp.zeros_like(merge_enable)

    state, split_acted, s_cand = _split_jobs(
        state, split_pids.astype(jnp.int32), split_enable
    )
    # Merges run after the splits (freed split pids are already invalid, so
    # they can't be picked as absorb targets); every ENABLED merge source
    # is barred as a target for every job — disabled rows are top_k filler
    # indices that must stay eligible as targets.
    state, merge_acted, m_cand = _merge_jobs(
        state, merge_pids.astype(jnp.int32), merge_enable,
        jnp.where(merge_enable, merge_pids, -1).astype(jnp.int32),
    )

    if cfg.enable_reassign:
        cand = tuple(
            jnp.concatenate([a, b], axis=0) for a, b in zip(s_cand, m_cand)
        )
        # Evaluation budget scales with the round's job count (overflow is
        # counted); the mover compaction inside keeps the append scatter at
        # reassign_budget rows regardless.  One wide GEMM + one scatter for
        # the whole round instead of two of each per job.
        state = _execute_reassigns(
            state, *cand,
            budget=max(cfg.reassign_budget, k * cfg.reassign_budget // 2),
        )

    did = jnp.sum(split_acted.astype(jnp.int32)) + jnp.sum(
        merge_acted.astype(jnp.int32)
    )
    return state, did


@functools.lru_cache(maxsize=None)
def _donating_round(jobs: int):
    """State-donating compile of `maintenance_round` (drain loops hand the
    round its own state back, so XLA updates the block pool in place
    instead of copying it every round)."""
    return jax.jit(
        lambda s: maintenance_round(s, jobs), donate_argnums=(0,)
    )


@functools.lru_cache(maxsize=None)
def _donating_round_access(jobs: int):
    """`_donating_round` with the access-histogram operand (first round of
    a drain folds the backend's pending probe counts)."""
    return jax.jit(
        lambda s, a: maintenance_round(s, jobs, a), donate_argnums=(0,)
    )


def rebuild_drain(
    state: IndexState,
    max_steps: int | None = None,
    jobs_per_round: int | None = None,
    *,
    donate: bool = False,
    access: Array | None = None,
) -> tuple[IndexState, int, int]:
    """Host-driven Local Rebuilder loop in batched rounds: run
    `maintenance_round` until quiescent, reading back ONE ``did_work``
    scalar per round (the old loop host-synced on a bool after every
    split+merge step).  Bounded by the convergence proof (≤ P_cap splits
    possible).

    ``max_steps`` caps the total jobs executed (the pre-round "steps"
    budget; the last round may overshoot by up to ``jobs_per_round - 1``).
    ``donate=True`` lets XLA mutate the caller's state buffers in place —
    only for callers that own them exclusively (`SPFreshIndex.maintain`).
    ``access`` (optional probe histogram) folds into the FIRST round's
    selection; later rounds of the same drain see it via the state.
    Returns ``(state, jobs_done, rounds)``.
    """
    cfg = state.cfg
    jobs = int(jobs_per_round or cfg.jobs_per_round)
    cap_jobs = max_steps if max_steps is not None else 2 * cfg.num_postings_cap
    step = _donating_round(jobs) if donate else (
        lambda s: maintenance_round(s, jobs)
    )
    step_a = _donating_round_access(jobs) if donate else (
        lambda s, a: maintenance_round(s, jobs, a)
    )
    done = 0
    rounds = 0
    while done < cap_jobs:
        if access is not None:
            state, did = step_a(state, jnp.asarray(access, jnp.int32))
            access = None
        else:
            state, did = step(state)
        rounds += 1
        d = int(did)  # the round's single device→host sync
        done += d
        if d == 0:
            break
    return state, done, rounds
