"""Index state and protocol configuration for SPFresh/LIRE.

Everything is fixed-capacity and functional: ``IndexState`` is a pytree whose
static geometry (capacities, protocol thresholds) lives in a hashable
``LireConfig`` aux field.  A LIRE operation is ``state' = op(state, ...)``
under jit.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.storage.blockpool import BlockPool, make_block_pool
from repro.utils.tree import field, pytree_dataclass

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LireConfig:
    """Static protocol + geometry parameters (hashable; pytree aux data)."""

    dim: int = 128
    # --- storage geometry ---
    block_size: int = 16            # vectors per block ("SSD block")
    max_blocks_per_posting: int = 8  # MB; posting capacity = BS*MB
    num_blocks: int = 4096           # B_cap
    num_postings_cap: int = 512      # P_cap
    num_vectors_cap: int = 65536     # N_cap (version map size)
    vector_dtype: str = "float32"    # storage dtype for posting payloads
    scan_dtype: str = "float32"      # distance-scan compute dtype (f32 accum)
    # --- tiered posting codec (storage/codec.py) ---
    # "fp32": hot tier stores vector_dtype verbatim (pre-codec behavior).
    # "bf16"/"int8": hot tier stores bf16 / per-posting-quantized int8
    #   (scan bytes ÷2 / ÷4) and a cold exact-fp32 tier serves maintenance
    #   reads and the search rerank.
    codec: str = "fp32"
    # Quantized scans over-fetch rerank_factor×k candidates, then rerank
    # the survivors against the exact tier before the final top-k.  1 =
    # no rerank (exact codecs don't need one).
    rerank_factor: int = 1
    # --- LIRE protocol ---
    split_limit: int = 96            # split when live length exceeds this
    merge_limit: int = 12            # merge when 0 < live length below this
    merge_fanout: int = 4            # nearest postings tried as merge absorbers
    reassign_range: int = 8          # nearby postings scanned after a split (paper: 64)
    reassign_budget: int = 256       # max vectors actually reassigned per pass
    replica_count: int = 4           # max closure replicas per vector (paper avg 5.47, max 8)
    replica_rng: float = 1.15        # replicate while d <= rng^2 * d_min (squared-L2 ratio)
    # --- maintenance batching (the Local Rebuilder round) ---
    # Jobs per `maintenance_round`: the top-K oversized postings are split
    # and the bottom-K undersized merged in ONE fused dispatch, with every
    # job's reassign candidates routed by a single GEMM.  1 degenerates to
    # the sequential `maintenance_step` work shape.
    jobs_per_round: int = 4
    # --- maintenance job selection (drift-aware cost model) ---
    # "size":  top-K longest / bottom-K shortest — the original selection,
    #          kept bit-identical as the parity baseline.
    # "drift": Ada-IVF-style cost-model ranking over the per-posting
    #          telemetry leaves: split priority ~ imbalance ×
    #          (1 + alpha·access_rate) + beta·drift, merge priority ~
    #          len × (1 + alpha·access_rate) ascending.  Eligibility is
    #          unchanged (only oversized postings split, only undersized
    #          merge); with all-zero telemetry the ranking degrades to the
    #          size ordering exactly.
    maintain_policy: str = "size"
    maintain_alpha: float = 1.0      # access-rate weight (drift policy)
    maintain_beta: float = 1.0       # centroid-drift weight (drift policy)
    # --- search ---
    nprobe: int = 8                  # postings probed per query (paper: 64)
    # --- split clustering ---
    kmeans_iters: int = 8
    # --- protocol ablations (benchmarks: SPANN+ / +split / full LIRE) ---
    enable_split: bool = True
    enable_merge: bool = True
    enable_reassign: bool = True
    # --- kernel integration (TPU target; interpret=True executes on CPU) ---
    use_pallas_nav: bool = False
    # Paged Pallas posting scan (search hot path).  False = XLA gather
    # oracle (`bp.parallel_get` + diff²), the default on CPU.  True streams
    # SSD-block-sized pages through the `posting_scan` kernels and emits
    # per-page k-min candidates — the (Q, nprobe·cap, d) gather buffer and
    # the (Q, nprobe·MB·BS) distance matrix are never materialized.
    use_pallas_scan: bool = False
    # "per_query": paper-faithful ParallelGET schedule — every probed page
    #   streamed once per (query, probe); HBM traffic = Q·nprobe·MB pages.
    # "batched": batch-dedup schedule — the micro-batch's probed pages are
    #   deduped and each unique page is streamed ONCE, scored against all
    #   Q queries with one MXU GEMM; traffic divides by the average probe
    #   multiplicity.
    scan_schedule: str = "per_query"
    # Static page budget for the batched schedule's fixed-shape dedup
    # compaction.  0 = lossless auto (min(Q·nprobe·MB, num_blocks)); a
    # smaller explicit budget bounds the kernel grid, dropping the
    # highest-numbered pages on overflow (counted, see `dedup_pages`).
    scan_page_budget: int = 0
    pallas_interpret: bool = True

    @property
    def posting_capacity(self) -> int:
        return self.block_size * self.max_blocks_per_posting

    def validate(self) -> None:
        assert self.split_limit <= self.posting_capacity, (
            "split_limit must fit in a posting"
        )
        assert self.merge_limit < self.split_limit
        assert self.merge_fanout >= 1
        assert self.jobs_per_round >= 1
        assert 2 * self.jobs_per_round <= self.num_postings_cap, (
            "a round allocates up to 2 pids per split job"
        )
        assert self.replica_count >= 1
        assert self.nprobe >= 1
        assert self.maintain_policy in ("size", "drift"), self.maintain_policy
        assert self.maintain_alpha >= 0.0
        assert self.maintain_beta >= 0.0
        assert self.scan_schedule in ("per_query", "batched"), self.scan_schedule
        assert self.scan_page_budget >= 0
        assert self.codec in ("fp32", "bf16", "int8"), self.codec
        assert self.rerank_factor >= 1


@pytree_dataclass
class LireStats:
    """Cumulative protocol counters (paper §5.2 reports these)."""

    n_inserts: Array        # external insert requests
    n_deletes: Array        # external delete requests
    n_appends: Array        # physical appends (inserts × replicas + reassigns)
    n_append_drops: Array   # appends dropped (posting/pool at capacity)
    n_splits: Array         # split actions executed
    n_gc_writebacks: Array  # split jobs resolved by GC-only write-back
    n_merges: Array         # merge actions executed
    n_reassign_checked: Array  # vectors evaluated by the NPA conditions
    n_reassign_candidates: Array  # vectors passing the necessary conditions
    n_reassigned: Array     # vectors actually reassigned (post NPA re-check)
    n_reassign_overflow: Array  # candidates dropped by reassign_budget

    @staticmethod
    def zeros() -> "LireStats":
        # Distinct buffers per counter: donated update steps (serve pipeline)
        # reject pytrees whose leaves alias the same buffer.
        return LireStats(*(jnp.zeros((), jnp.int32) for _ in range(11)))


@pytree_dataclass
class LireTelemetry:
    """Per-posting maintenance telemetry (Ada-IVF cost-model inputs).

    All three leaves live in ``IndexState`` and are bumped ONLY inside the
    jitted update/maintenance steps, so WAL replay reproduces them
    bit-exactly.  Search probes are the one externally-sourced signal:
    they accumulate host-side in the serving backend and enter the state
    as an explicit operand of the next WAL-logged maintenance dispatch.
    """

    access_count: Array  # (P_cap,) i32 — search probes, folded at dispatch
    update_count: Array  # (P_cap,) i32 — appends landed since (re)creation
    drift_vec: Array     # (P_cap, d) f32 — summed x - centroid[pid] since split

    @staticmethod
    def zeros(cfg: "LireConfig") -> "LireTelemetry":
        p = cfg.num_postings_cap
        return LireTelemetry(
            access_count=jnp.zeros((p,), jnp.int32),
            update_count=jnp.zeros((p,), jnp.int32),
            drift_vec=jnp.zeros((p, cfg.dim), jnp.float32),
        )


@pytree_dataclass
class IndexState:
    cfg: LireConfig = field(static=True)
    pool: BlockPool
    centroids: Array        # (P_cap, d) f32
    centroid_sqn: Array     # (P_cap,) f32 cached ||c||^2
    centroid_valid: Array   # (P_cap,) bool
    versions: Array         # (N_cap,) u8 — 7-bit version + deletion bit
    pid_free_stack: Array   # (P_cap,) i32
    pid_free_top: Array     # () i32
    rng: Array              # PRNG key for split clustering
    step: Array             # () i32 monotonically increasing op counter
    next_vid: Array         # () i32 — local slot allocator (distributed insert)
    stats: LireStats
    # NOTE: keep `telemetry` LAST — snapshots written before it existed are
    # migrated by reconstructing the missing trailing leaves as zeros
    # (storage/snapshot.py).
    telemetry: LireTelemetry

    @property
    def n_postings(self) -> Array:
        return jnp.sum(self.centroid_valid.astype(jnp.int32))


def make_empty_state(cfg: LireConfig, seed: int = 0) -> IndexState:
    cfg.validate()
    dtype = jnp.dtype(cfg.vector_dtype)
    pool = make_block_pool(
        num_blocks=cfg.num_blocks,
        block_size=cfg.block_size,
        dim=cfg.dim,
        num_postings_cap=cfg.num_postings_cap,
        max_blocks_per_posting=cfg.max_blocks_per_posting,
        dtype=dtype,
        codec=cfg.codec,
    )
    p = cfg.num_postings_cap
    return IndexState(
        cfg=cfg,
        pool=pool,
        centroids=jnp.zeros((p, cfg.dim), jnp.float32),
        centroid_sqn=jnp.zeros((p,), jnp.float32),
        centroid_valid=jnp.zeros((p,), bool),
        # +1: reserved scratch slot for disabled scatter rows (see versionmap).
        versions=jnp.zeros((cfg.num_vectors_cap + 1,), jnp.uint8),
        pid_free_stack=jnp.arange(p, dtype=jnp.int32),
        pid_free_top=jnp.asarray(p, jnp.int32),
        rng=jax.random.PRNGKey(seed),
        step=jnp.asarray(0, jnp.int32),
        next_vid=jnp.asarray(0, jnp.int32),
        stats=LireStats.zeros(),
        telemetry=LireTelemetry.zeros(cfg),
    )


def alloc_pid(state: IndexState, enable: Array) -> tuple[IndexState, Array]:
    """Pop a posting id from the free stack (-1 on exhaustion/no-op)."""
    has = enable & (state.pid_free_top > 0)
    top = jnp.maximum(state.pid_free_top - 1, 0)
    pid = jnp.where(has, state.pid_free_stack[top], -1)
    state = state.replace(
        pid_free_top=jnp.where(has, top, state.pid_free_top)
    )
    return state, pid


def free_pid(state: IndexState, pid: Array, enable: Array) -> IndexState:
    do = enable & (pid >= 0)
    safe = jnp.maximum(pid, 0)
    stack = jnp.where(
        do,
        state.pid_free_stack.at[state.pid_free_top].set(pid.astype(jnp.int32)),
        state.pid_free_stack,
    )
    valid = jnp.where(
        do, state.centroid_valid.at[safe].set(False),
        state.centroid_valid,
    )
    # Freed pids come back off the stack with zero telemetry — the leaves
    # always describe the CURRENT posting living at a pid.
    tel = state.telemetry
    tel = tel.replace(
        access_count=jnp.where(
            do, tel.access_count.at[safe].set(0), tel.access_count
        ),
        update_count=jnp.where(
            do, tel.update_count.at[safe].set(0), tel.update_count
        ),
        drift_vec=jnp.where(
            do, tel.drift_vec.at[safe].set(0.0), tel.drift_vec
        ),
    )
    return state.replace(
        pid_free_stack=stack,
        pid_free_top=jnp.where(do, state.pid_free_top + 1, state.pid_free_top),
        centroid_valid=valid,
        telemetry=tel,
    )


def alloc_pids(state: IndexState, enable: Array) -> tuple[IndexState, Array]:
    """Batched pid alloc: pop one id per enabled row, in ONE gather.

    Pops follow the sequential `alloc_pid` LIFO order (row with the i-th
    True gets ``stack[top - i]``); rows past stack exhaustion get ``-1``.
    Returns ``(state, pids (k,))``.
    """
    cnt = jnp.cumsum(enable.astype(jnp.int32))  # inclusive
    pos = state.pid_free_top - cnt
    ok = enable & (pos >= 0)
    pids = jnp.where(ok, state.pid_free_stack[jnp.maximum(pos, 0)], -1)
    return (
        state.replace(pid_free_top=state.pid_free_top - jnp.sum(ok)),
        pids.astype(jnp.int32),
    )


def free_pids(state: IndexState, pids: Array, enable: Array) -> IndexState:
    """Batched `free_pid`: push ``k`` (distinct) ids back in ONE scatter and
    invalidate their centroids."""
    do = enable & (pids >= 0)
    pos = state.pid_free_top + jnp.cumsum(do.astype(jnp.int32)) - 1
    cap = state.pid_free_stack.shape[0]
    stack = state.pid_free_stack.at[jnp.where(do, pos, cap)].set(
        pids.astype(jnp.int32), mode="drop"
    )
    tgt = jnp.where(do, jnp.maximum(pids, 0), cap)
    valid = state.centroid_valid.at[tgt].set(False, mode="drop")
    tel = state.telemetry
    tel = tel.replace(
        access_count=tel.access_count.at[tgt].set(0, mode="drop"),
        update_count=tel.update_count.at[tgt].set(0, mode="drop"),
        drift_vec=tel.drift_vec.at[tgt].set(0.0, mode="drop"),
    )
    return state.replace(
        pid_free_stack=stack,
        pid_free_top=state.pid_free_top + jnp.sum(do),
        centroid_valid=valid,
        telemetry=tel,
    )


def set_centroids(
    state: IndexState, pids: Array, centroids: Array, enable: Array
) -> IndexState:
    """Batched `set_centroid`: ``k`` (distinct) centroid writes in ONE
    scatter.  ``centroids (k, d)``; disabled rows are dropped."""
    do = enable & (pids >= 0)
    cap = state.centroids.shape[0]
    tgt = jnp.where(do, jnp.maximum(pids, 0), cap)
    c = centroids.astype(jnp.float32)
    return state.replace(
        centroids=state.centroids.at[tgt].set(c, mode="drop"),
        centroid_sqn=state.centroid_sqn.at[tgt].set(
            jnp.sum(c * c, axis=-1), mode="drop"
        ),
        centroid_valid=state.centroid_valid.at[tgt].set(True, mode="drop"),
    )


def set_centroid(
    state: IndexState, pid: Array, centroid: Array, enable: Array
) -> IndexState:
    safe = jnp.maximum(pid, 0)
    do = enable & (pid >= 0)
    c = centroid.astype(jnp.float32)
    centroids = jnp.where(do, state.centroids.at[safe].set(c), state.centroids)
    sqn = jnp.where(
        do, state.centroid_sqn.at[safe].set(jnp.sum(c * c)), state.centroid_sqn
    )
    valid = jnp.where(
        do, state.centroid_valid.at[safe].set(True), state.centroid_valid
    )
    return state.replace(
        centroids=centroids, centroid_sqn=sqn, centroid_valid=valid
    )


def bump_stat(stats: LireStats, name: str, amount) -> LireStats:
    return stats.replace(
        **{name: getattr(stats, name) + jnp.asarray(amount, jnp.int32)}
    )
