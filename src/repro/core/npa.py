"""NPA (Nearest Partition Assignment) necessary conditions — paper §3.3.

After a split of posting with (deleted) centroid ``A_o`` into new centroids
``A_1, A_2``:

* Eq. (1): a vector ``v`` that lived in the old posting must be *checked* for
  reassignment iff  ``D(v, A_o) <= D(v, A_i)  for all i in {1,2}``.
* Eq. (2): a vector ``v`` living in a nearby posting ``B`` must be *checked*
  iff             ``D(v, A_i) <= D(v, A_o)  for some i in {1,2}``.

These are *necessary* conditions: they bound the candidate set; the actual
reassignment does a full nearest-posting search afterwards (false positives
are dropped there).  Both are pure vectorized distance comparisons here.

For a *merge* (old centroid deleted, vectors appended to a surviving posting)
every vector of the deleted posting is a candidate (paper §3.3: "only vectors
from deleted posting require reassignment").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.distance import sql2

Array = jax.Array


def split_old_posting_candidates(
    v: Array, old_centroid: Array, new_centroids: Array
) -> Array:
    """Eq. (1) over a batch ``v (n, d)``.

    Returns bool ``(n,)`` — True when the vector must be *checked*.
    ``new_centroids`` is ``(2, d)``.
    """
    d_old = sql2(v, old_centroid[None, :])  # (n,)
    d_new = jax.vmap(lambda c: sql2(v, c[None, :]), out_axes=1)(new_centroids)
    # (n, 2): distance to each new centroid
    return jnp.all(d_old[:, None] <= d_new, axis=-1)


def split_neighbor_candidates(
    v: Array, old_centroid: Array, new_centroids: Array
) -> Array:
    """Eq. (2) over a batch ``v (n, d)`` of vectors in *nearby* postings.

    Returns bool ``(n,)`` — True when the vector must be *checked*.
    """
    d_old = sql2(v, old_centroid[None, :])
    d_new = jax.vmap(lambda c: sql2(v, c[None, :]), out_axes=1)(new_centroids)
    return jnp.any(d_new <= d_old[:, None], axis=-1)
