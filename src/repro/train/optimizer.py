"""AdamW + gradient clipping + LR schedules, written against raw pytrees
(no optax dependency).  Optimizer state shards exactly like the params —
the dry-run passes the same PartitionSpecs for both.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    ratio = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * ratio


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_update(
    grads: Any, opt_state: dict, params: Any, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = opt_state["count"] + 1
    lr = schedule(cfg, count)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v2 / (1 - cfg.b2 ** count.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "count": count},
        {"grad_norm": gnorm, "lr": lr},
    )


def make_train_step(
    loss_fn: Callable[[Any, dict], tuple[Array, dict]],
    opt_cfg: AdamWConfig,
):
    """Generic fused train step: grads + clip + AdamW.

    ``loss_fn(params, batch) -> (loss, metrics)``.
    """

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
