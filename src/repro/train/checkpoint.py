"""Training checkpoint store: atomic snapshots of (params, opt_state, step)
with retention — reuses the index snapshot machinery (storage/snapshot.py).
"""
from __future__ import annotations

import os
import re
from typing import Any

from repro.storage.snapshot import load_snapshot, save_snapshot, snapshot_exists

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointStore:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            m = _STEP_RE.match(name)
            if m and snapshot_exists(os.path.join(self.root, name)):
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, step: int, state: Any, extra: dict | None = None) -> None:
        save_snapshot(self._path(step), state, step=step, extra=extra)
        for old in self.steps()[: -self.keep]:
            import shutil

            shutil.rmtree(self._path(old), ignore_errors=True)

    def restore_latest(self, template: Any) -> tuple[Any, int, dict] | None:
        steps = self.steps()
        if not steps:
            return None
        state, manifest = load_snapshot(self._path(steps[-1]), template)
        return state, manifest["step"], manifest.get("extra", {})
