"""Training substrate: in-house AdamW, schedules, trainer with
checkpoint/restart."""
from repro.train.optimizer import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
    make_train_step,
)
