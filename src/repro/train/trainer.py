"""Fault-tolerant training loop.

Production posture on a real cluster:
  * periodic atomic checkpoints (params + optimizer + data cursor), restart
    resumes from the latest complete one — a preempted/failed node restarts
    the whole SPMD program from the checkpoint (the standard TPU recovery
    model; per-core recovery does not exist under SPMD);
  * step-time watchdog (straggler detection): steps slower than
    ``straggler_factor ×`` the running median are logged and counted — on a
    real fleet this feeds the scheduler's replace-node decision;
  * data pipeline is a deterministic cursor (step → batch), so restarts
    replay the exact token stream.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.train.checkpoint import CheckpointStore
from repro.train.optimizer import AdamWConfig, adamw_init, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    keep_checkpoints: int = 3
    straggler_factor: float = 3.0


class Trainer:
    def __init__(
        self,
        *,
        loss_fn: Callable[[Any, dict], tuple[Any, dict]],
        init_params_fn: Callable[[], Any],
        batch_fn: Callable[[int], dict],
        opt_cfg: AdamWConfig,
        trainer_cfg: TrainerConfig,
        ckpt_dir: str | None = None,
        jit_step: bool = True,
    ):
        self.cfg = trainer_cfg
        self.batch_fn = batch_fn
        step = make_train_step(loss_fn, opt_cfg)
        self.step_fn = jax.jit(step, donate_argnums=(0, 1)) if jit_step else step
        self.store = (
            CheckpointStore(ckpt_dir, keep=trainer_cfg.keep_checkpoints)
            if ckpt_dir else None
        )
        self._init_params_fn = init_params_fn
        self.params = None
        self.opt_state = None
        self.step = 0
        self.history: list[dict] = []
        self.straggler_steps = 0

    # ------------------------------------------------------------------
    def _initialize(self) -> None:
        template_p = self._init_params_fn()
        template_o = adamw_init(template_p)
        if self.store is not None:
            restored = self.store.restore_latest((template_p, template_o))
            if restored is not None:
                (self.params, self.opt_state), self.step, _ = restored
                return
        self.params, self.opt_state = template_p, template_o
        self.step = 0

    # ------------------------------------------------------------------
    def run(self, steps: int | None = None) -> dict:
        if self.params is None:
            self._initialize()
        target = self.step + steps if steps is not None else self.cfg.total_steps
        target = min(target, self.cfg.total_steps)
        durations: list[float] = []
        while self.step < target:
            batch = self.batch_fn(self.step)
            t0 = time.time()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            durations.append(dt)
            med = float(np.median(durations[-50:]))
            if len(durations) > 5 and dt > self.cfg.straggler_factor * med:
                self.straggler_steps += 1
            self.step += 1
            if self.step % self.cfg.log_every == 0 or self.step == target:
                self.history.append(
                    {"step": self.step, "loss": float(metrics["loss"]),
                     "dt": dt}
                )
            if self.store is not None and (
                self.step % self.cfg.checkpoint_every == 0
                or self.step == self.cfg.total_steps
            ):
                self.store.save(
                    self.step, (self.params, self.opt_state),
                    extra={"straggler_steps": self.straggler_steps},
                )
        return {
            "final_step": self.step,
            "final_loss": self.history[-1]["loss"] if self.history else None,
            "straggler_steps": self.straggler_steps,
            "history": self.history,
        }
